"""Train any assigned architecture (reduced size on CPU) end-to-end.

    PYTHONPATH=src python examples/train_lm.py --arch mixtral-8x7b --steps 30
Thin wrapper over the launcher so the example stays one import away from prod.
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    if "--reduced" not in sys.argv:
        sys.argv.append("--reduced")
    if not any(a.startswith("--arch") for a in sys.argv):
        sys.argv += ["--arch", "granite-3-8b"]
    sys.exit(main())
