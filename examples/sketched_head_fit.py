"""Fit a linear probe on frozen LM features with Algorithm 1 (paper → LLM bridge).

Extracts final-hidden-state features from a reduced backbone over a synthetic token
stream, then fits a next-token linear head by distributed sketch-and-solve with the
privacy accountant on — the features never leave the "master" unsketched.

    PYTHONPATH=src python examples/sketched_head_fit.py --arch chatglm3-6b
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.core import privacy, sketches as sk
from repro.data import lm_batch
from repro.models import lm
from repro.train import solvers


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="chatglm3-6b")
    ap.add_argument("--batches", type=int, default=8)
    ap.add_argument("--q", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key)

    feats, targets = [], []
    for step in range(args.batches):
        batch = lm_batch(0, step, batch=4, seq=64, vocab=cfg.vocab_size)
        H = solvers.extract_features(params, cfg, batch)
        feats.append(H[:-1])
        # regression target: embedding of the next token (a contextual probe)
        emb = params["embed"]["table"][batch["tokens"].reshape(-1)[1:]]
        targets.append(emb.astype(jnp.float32))
    H = jnp.concatenate(feats)
    Y = jnp.concatenate(targets)
    print(f"features {H.shape}, targets {Y.shape}")

    acc = privacy.PrivacyAccountant()
    spec = sk.SketchSpec("sjlt", m=4 * cfg.d_model, s=4)
    W = solvers.fit_head(key, H, Y, spec, q=args.q, accountant=acc)
    quality = solvers.head_fit_quality(H, Y, W)
    print(f"f* = {quality['f_star']:.4f}  f(sketched) = {quality['f_sketch']:.4f}  "
          f"rel_err = {quality['rel_err']:.4f}")
    print(acc.report())


if __name__ == "__main__":
    main()
