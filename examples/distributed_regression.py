"""End-to-end driver: distributed sketched regression with straggler simulation.

Runs Algorithm 1 over a real jax mesh (shard_map workers + masked psum averaging),
with failures/deadline stragglers injected, multi-round elastic scaling, and the
privacy accountant on. Uses whatever devices exist (1 on this container — the mesh
logic is identical on a pod).

    PYTHONPATH=src python examples/distributed_regression.py --n 200000 --d 256 --workers 8
"""
import argparse

import jax
import jax.numpy as jnp

from repro.core import averaging, distributed, privacy, sketches as sk, solve, theory
from repro.data import student_t_regression


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=100_000)
    ap.add_argument("--d", type=int, default=128)
    ap.add_argument("--m", type=int, default=0, help="sketch dim (default 8d)")
    ap.add_argument("--workers", type=int, default=8, help="logical workers (rounds x devices)")
    ap.add_argument("--sketch", default="gaussian", choices=list(sk.KINDS))
    ap.add_argument("--drop-prob", type=float, default=0.1)
    ap.add_argument("--deadline-quantile", type=float, default=0.9)
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    A, b, _ = student_t_regression(key, args.n, args.d, df=2.5)
    x_star = solve.lstsq(A, b)
    f_star = float(solve.residual_cost(A, b, x_star))
    m = args.m or 8 * args.d
    spec = sk.SketchSpec(
        args.sketch, m, m_prime=4 * m if args.sketch == "hybrid" else 0
    )

    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev,), ("data",))
    rounds = max(1, args.workers // n_dev)
    q = n_dev * rounds
    print(f"devices={n_dev} rounds={rounds} -> q={q} workers, sketch={args.sketch} m={m}")

    # privacy accounting: the master ships q sketched copies
    acc = privacy.PrivacyAccountant()
    for w in range(q):
        acc.record(m, args.n, tag=f"worker{w}")
    print(acc.report())

    # straggler mask over all q logical workers
    mask = averaging.simulate_straggler_mask(
        jax.random.PRNGKey(1), q, drop_prob=args.drop_prob, deadline_quantile=args.deadline_quantile
    )
    arrived = int(mask.sum())

    # run Algorithm 1 round by round (elastic: each round is a fresh worker wave)
    acc_avg = averaging.StreamingAverage.init(args.d)
    for r in range(rounds):
        round_mask = mask[r * n_dev : (r + 1) * n_dev]
        if int(round_mask.sum()) == 0:
            # every worker of this wave straggled: there is nothing to average
            # (the eager driver raises on an empty round) — the master just moves
            # on to the next wave, exactly like the serverless deployment.
            print(f"round {r}: all workers straggled, skipping")
            continue
        xbar_r = distributed.distributed_sketch_solve(
            mesh, spec, key, A, b, straggler_mask=round_mask, round_id=r
        )
        # weight the round by its realized worker count
        for _ in range(int(round_mask.sum())):
            acc_avg = acc_avg.update(xbar_r)
    xbar = acc_avg.mean

    err = float(solve.relative_error(A, b, xbar, f_star))
    print(f"\narrived {arrived}/{q} workers (stragglers dropped, average unchanged in expectation)")
    print(f"rel_err = {err:.6f}")
    if args.sketch == "gaussian":
        print(f"Thm 1 with realized q'={arrived}: {theory.gaussian_averaged_error(m, args.d, max(arrived,1)):.6f}")


if __name__ == "__main__":
    main()
