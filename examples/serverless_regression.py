"""Serverless Algorithm 1 on the async runtime engine.

The paper's deployment, end to end: the master invokes q stateless sketch-solve
lambdas, runtimes are drawn from a seeded latency model (lognormal / heavy-tail /
hard-drop), results fold into a streaming average the moment they arrive, blown
deadlines are retried with *fresh* i.i.d. sketches, and the run stops early once
the estimate's error crosses the target — the master never waits for the tail.

    PYTHONPATH=src python examples/serverless_regression.py --n 50000 --d 64 --workers 32
    PYTHONPATH=src python examples/serverless_regression.py --latency heavytail --target 1e-2
"""
import argparse
import os

import jax

from repro import runtime as rt
from repro.core import sketches as sk, solve, theory
from repro.data import student_t_regression


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=50_000)
    ap.add_argument("--d", type=int, default=64)
    ap.add_argument("--m", type=int, default=0, help="sketch dim (default 8d)")
    ap.add_argument("--workers", type=int, default=32)
    ap.add_argument("--sketch", default="gaussian", choices=list(sk.KINDS))
    ap.add_argument("--latency", default="harddrop", choices=["lognormal", "heavytail", "harddrop"])
    ap.add_argument("--deadline", type=float, default=2.0)
    ap.add_argument("--retries", type=int, default=2)
    ap.add_argument("--target", type=float, default=0.0, help="early-stop rel-error target (0 = off)")
    ap.add_argument("--seed", type=int, default=5)
    ap.add_argument("--events-out", default="", help="write the JSONL event log here")
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    A, b, _ = student_t_regression(key, args.n, args.d, df=2.5)
    x_star = solve.lstsq(A, b)
    f_star = float(solve.residual_cost(A, b, x_star))
    m = args.m or 8 * args.d
    spec = sk.SketchSpec(args.sketch, m, m_prime=4 * m if args.sketch == "hybrid" else 0)

    lognormal = rt.LognormalLatency(seed=args.seed, mean_s=1.0, sigma=0.35)
    latency = {
        "lognormal": lognormal,
        "heavytail": rt.HeavyTailLatency(seed=args.seed, scale_s=0.7, alpha=1.3),
        "harddrop": rt.DropLatency(seed=args.seed, inner=lognormal, drop_prob=0.25),
    }[args.latency]
    cfg = rt.RuntimeConfig(
        deadline_s=args.deadline, max_retries=args.retries,
        target_error=args.target or None, min_results=2,
    )
    print(f"q={args.workers} {args.sketch} m={m}  latency={args.latency}  "
          f"deadline={args.deadline}s retries={args.retries}"
          + (f"  target={args.target}" if args.target else ""))

    res = rt.serverless_sketch_solve(
        spec, key, A, b, q=args.workers, latency=latency, config=cfg, error_fn="probe",
    )

    print("\nerror-vs-wallclock (simulated):")
    trace = res.events.error_trace()
    for t, count, err in trace[:: max(1, len(trace) // 10)]:
        print(f"  t={t:7.3f}s  q'={count:3d}  probe rel_err={err:.5f}")

    err = float(solve.relative_error(A, b, res.xbar, f_star))
    s = res.summary(deadline=args.deadline)
    print(f"\narrived {res.count}/{res.submitted} tasks "
          f"({s['retries']} retries, {s['timeouts']} timeouts, "
          f"{s['cancelled']} cancelled{', stopped early' if res.stopped_early else ''})")
    print(f"sim makespan {s['sim_makespan_s']:.2f}s   p50/p95 latency "
          f"{s.get('p50_latency_s', float('nan')):.2f}/{s.get('p95_latency_s', float('nan')):.2f}s")
    print(f"true rel_err = {err:.6f}")
    if args.sketch == "gaussian":
        print(f"Thm 1 with realized q'={res.count}: "
              f"{theory.gaussian_averaged_error(m, args.d, max(res.count, 1)):.6f}")
    if args.events_out:
        path = res.events.to_jsonl(os.path.abspath(args.events_out))
        print(f"event log: {path} ({len(res.events)} events)")


if __name__ == "__main__":
    main()
