"""Quickstart: the paper's Algorithm 1 in 30 seconds on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import sketches as sk, solve, theory, privacy
from repro.utils import prng

# a tall least-squares problem (n >> d)
key = jax.random.PRNGKey(0)
n, d, m, q = 20_000, 50, 400, 16
A = jax.random.normal(key, (n, d))
b = A @ jax.random.normal(jax.random.PRNGKey(1), (d,)) + jax.random.normal(jax.random.PRNGKey(2), (n,))

x_star = solve.lstsq(A, b)
f_star = float(solve.residual_cost(A, b, x_star))

# Algorithm 1: q i.i.d. Gaussian-sketch workers, averaged
spec = sk.SketchSpec("gaussian", m)
xs = jax.vmap(lambda w: solve.sketch_and_solve(spec, prng.worker_key(key, w), A, b))(jnp.arange(q))
for k in (1, 4, q):
    xbar = jnp.mean(xs[:k], axis=0)
    err = float(solve.relative_error(A, b, xbar, f_star))
    print(f"q={k:3d}  rel_err={err:.5f}   (Thm 1 expectation: {theory.gaussian_averaged_error(m, d, k):.5f})")

# the privacy side: what does shipping S_kA leak about A?
print(f"\nEq.5 MI bound per entry: {privacy.mi_per_entry_bound(m, n):.2e} nats "
      f"(m/n = {m/n:.3f}); at the paper's airline scale it is "
      f"{privacy.mi_per_entry_bound(int(5e5), int(1.21e8)):.2e}")

# other sketch families, one line each
for kind in ("srht", "uniform", "leverage", "sjlt"):
    xk = solve.sketch_and_solve(sk.SketchSpec(kind, m), jax.random.PRNGKey(9), A, b)
    print(f"{kind:9s} single-sketch rel_err = {float(solve.relative_error(A, b, xk, f_star)):.5f}")
