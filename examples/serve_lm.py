"""Serve a reduced model with batched requests through the engine.

    PYTHONPATH=src python examples/serve_lm.py --arch granite-3-8b
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    if "--reduced" not in sys.argv:
        sys.argv.append("--reduced")
    if not any(a.startswith("--arch") for a in sys.argv):
        sys.argv += ["--arch", "granite-3-8b"]
    sys.exit(main())
