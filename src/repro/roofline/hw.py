"""Hardware constants (TPU v5e target, per the assignment brief)."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HwSpec:
    name: str
    peak_flops_bf16: float       # FLOP/s per chip
    hbm_bw: float                # bytes/s per chip
    ici_link_bw: float           # bytes/s per link (one direction)
    ici_links: int               # links per chip participating in a ring
    hbm_bytes: float             # capacity per chip
    dcn_bw: float                # bytes/s per chip for cross-pod traffic


V5E = HwSpec(
    name="tpu_v5e",
    peak_flops_bf16=197e12,
    hbm_bw=819e9,
    ici_link_bw=50e9,
    ici_links=4,                 # 2D torus: ±x, ±y
    hbm_bytes=16 * 1024**3,
    dcn_bw=6.25e9,               # ~50 Gb/s effective per chip across pods
)
