"""Parse collective ops out of post-SPMD HLO text and cost them.

``compiled.as_text()`` is the per-device module: shapes are per-participant. For each
collective instruction we record (kind, result bytes, group size) and convert to a
wire-time estimate with the standard ring-algorithm factors:

    all-gather        (P-1)/P · out_bytes          per device
    reduce-scatter    (P-1)/P · in_bytes           per device
    all-reduce        2·(P-1)/P · bytes            (RS + AG)
    all-to-all        (P-1)/P · bytes
    collective-permute  bytes                      (one hop)

Cross-pod groups (any group spanning a pod boundary) are costed at DCN bandwidth
instead of ICI — detected from the device ids in the replica group when the caller
passes ``pod_size``.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")

# e.g.  %all-gather.7 = f32[4096,512]{1,0} all-gather(%x), channel_id=1, replica_groups=...
_INSTR_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+)\[([\d,]*)\](?:\{[^}]*\})?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[")
_SOURCE_TARGET_RE = re.compile(r"source_target_pairs=\{\{(\d+),(\d+)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    bytes: int            # per-device result/operand bytes
    group_size: int
    crosses_pod: bool
    line: str


def parse_collectives(hlo_text: str, *, pod_size: Optional[int] = None) -> List[CollectiveOp]:
    ops: List[CollectiveOp] = []
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if m is None:
            continue
        tuple_body, dtype, dims, kind = m.group(1), m.group(2), m.group(3), m.group(4)
        if tuple_body is not None:
            nbytes = sum(_shape_bytes(dt, dm) for dt, dm in _SHAPE_RE.findall(tuple_body))
        else:
            nbytes = _shape_bytes(dtype, dims)
        group: List[int] = []
        gs = 1
        gm = _GROUPS_BRACE_RE.search(line)
        if gm:
            group = [int(x) for x in gm.group(1).split(",")]
            gs = len(group)
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            if gi:
                # replica_groups=[num_groups, group_size]<=[...]
                gs = int(gi.group(2))
        crosses = False
        if pod_size:
            if group:
                crosses = len({g // pod_size for g in group}) > 1
            else:
                # iota groups: conservatively flag groups larger than a pod, and
                # permutes whose explicit pairs span pods.
                crosses = gs > pod_size
        st = _SOURCE_TARGET_RE.search(line)
        if pod_size and st:
            crosses = crosses or (int(st.group(1)) // pod_size != int(st.group(2)) // pod_size)
        ops.append(CollectiveOp(kind, nbytes, gs, crosses, line.strip()[:160]))
    return ops


def op_wire_bytes(op: CollectiveOp) -> float:
    """Per-device bytes that actually traverse links (ring-algorithm accounting)."""
    p = max(op.group_size, 1)
    frac = (p - 1) / p if p > 1 else 0.0
    if op.kind == "all-reduce":
        return 2.0 * frac * op.bytes
    if op.kind in ("all-gather", "reduce-scatter", "all-to-all"):
        return frac * op.bytes
    if op.kind == "collective-permute":
        return float(op.bytes)
    return float(op.bytes)


def collective_seconds(
    ops: List[CollectiveOp], *, ici_bw: float, dcn_bw: Optional[float] = None
) -> Dict[str, float]:
    """Aggregate wire time per device. Returns totals + per-kind breakdown."""
    out: Dict[str, float] = {"total_s": 0.0, "total_bytes": 0.0, "dcn_s": 0.0, "n_ops": float(len(ops))}
    for op in ops:
        wb = op_wire_bytes(op)
        bw = dcn_bw if (op.crosses_pod and dcn_bw) else ici_bw
        t = wb / bw
        out["total_s"] += t
        out["total_bytes"] += wb
        if op.crosses_pod and dcn_bw:
            out["dcn_s"] += t
        k = f"{op.kind}_s"
        out[k] = out.get(k, 0.0) + t
    return out


def summarize_collectives(ops: List[CollectiveOp]) -> Dict[str, Dict[str, float]]:
    """Count + bytes per collective kind (the EXPERIMENTS.md schedule table)."""
    agg: Dict[str, Dict[str, float]] = {}
    for op in ops:
        e = agg.setdefault(op.kind, {"count": 0, "bytes": 0.0, "wire_bytes": 0.0})
        e["count"] += 1
        e["bytes"] += op.bytes
        e["wire_bytes"] += op_wire_bytes(op)
    return agg
