"""Render the dry-run JSONs into the EXPERIMENTS.md §Dry-run / §Roofline tables.

    PYTHONPATH=src python -m repro.roofline.report [--dir results/dryrun] [--mesh pod16x16]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

GiB = 1024**3


def load(dir_: str) -> List[Dict]:
    recs = []
    for p in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def _fmt_terms(r) -> str:
    rl = r["roofline"]
    return f"{rl['compute_s']*1e3:9.1f} | {rl['memory_s']*1e3:9.1f} | {rl['collective_s']*1e3:9.1f}"


def dryrun_table(recs: List[Dict], mesh: str) -> str:
    lines = [
        "| arch | shape | status | compile_s | args GiB | temp GiB | fits 16G | collective schedule (count×kind) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["mesh"] != mesh:
            continue
        if r["status"] != "OK":
            reason = r.get("reason", r.get("error", ""))[:60]
            lines.append(f"| {r['arch']} | {r['shape']} | {r['status']} | — | — | — | — | {reason} |")
            continue
        m = r["memory"]
        sched = ", ".join(
            f"{int(v['count'])}×{k}" for k, v in sorted(r["collectives"].items())
        ) or "none"
        lines.append(
            f"| {r['arch']} | {r['shape']} | OK | {r['compile_s']} | "
            f"{m['argument_bytes']/GiB:.2f} | {m['temp_bytes']/GiB:.2f} | "
            f"{'✓' if r['fits_16gb_hbm'] else '✗'} | {sched} |"
        )
    return "\n".join(lines)


def roofline_table(recs: List[Dict], mesh: str) -> str:
    lines = [
        "| arch | shape | compute ms | memory ms | collective ms | bottleneck | MODEL_FLOPS | useful frac | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["mesh"] != mesh or r["status"] != "OK":
            continue
        rl = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_terms(r)} | {rl['bottleneck']} | "
            f"{rl['model_flops']:.2e} | {rl['useful_fraction']:.2f} | {rl['roofline_fraction']:.3f} |"
        )
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    default_dir = os.path.normpath(
        os.path.join(os.path.dirname(__file__), "..", "..", "..", "results", "dryrun")
    )
    ap.add_argument("--dir", default=default_dir)
    args = ap.parse_args()
    recs = load(args.dir)
    for mesh in ("pod16x16", "pod2x16x16"):
        if not any(r["mesh"] == mesh for r in recs):
            continue
        print(f"\n### Dry-run — {mesh}\n")
        print(dryrun_table(recs, mesh))
        print(f"\n### Roofline — {mesh}\n")
        print(roofline_table(recs, mesh))
    n_ok = sum(r["status"] == "OK" for r in recs)
    n_fit = sum(r.get("fits_16gb_hbm", False) for r in recs)
    print(f"\n{n_ok} OK cells, {n_fit} fit 16 GiB HBM, "
          f"{sum(r['status']=='SKIP' for r in recs)} skips, {sum(r['status']=='FAIL' for r in recs)} fails")


if __name__ == "__main__":
    main()
