"""Three-term roofline from compiled dry-run artifacts.

    compute    = HLO_FLOPs_per_device / peak_FLOP/s
    memory     = HLO_bytes_per_device / HBM_bw
    collective = Σ wire_bytes(op) / link_bw        (per device; DCN-aware)

``cost_analysis()`` on the post-SPMD module reports *per-device* flops/bytes, so no
division by chip count is needed (verified against a hand-checked matmul).
MODEL_FLOPS = 6·N·D (training) / 2·N·D (inference) with N = active params — the
"useful work" yardstick; MODEL_FLOPS / (HLO_FLOPs · chips) exposes remat and
redundant-compute overhead.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.roofline.hw import HwSpec, V5E
from repro.roofline import collectives as C


@dataclasses.dataclass
class RooflineResult:
    arch: str
    shape: str
    mesh: str
    chips: int
    # raw
    flops_per_device: float
    bytes_per_device: float
    collective: Dict[str, float]
    # terms (seconds)
    compute_s: float
    memory_s: float
    collective_s: float
    # derived
    bottleneck: str
    step_s: float                  # max of the three (perfect-overlap lower bound)
    model_flops: float             # 6·N·D or 2·N·D, global
    useful_fraction: float         # model_flops / (flops_per_device · chips)
    roofline_fraction: float       # compute_s / step_s  (1.0 = compute-bound at peak)
    memory_analysis: Optional[Dict[str, float]] = None

    def table_row(self) -> str:
        return (
            f"| {self.arch} | {self.shape} | {self.mesh} | "
            f"{self.compute_s*1e3:.2f} | {self.memory_s*1e3:.2f} | {self.collective_s*1e3:.2f} | "
            f"{self.bottleneck} | {self.useful_fraction:.2f} | {self.roofline_fraction:.2f} |"
        )


def roofline_terms(
    *,
    arch: str,
    shape: str,
    mesh: str,
    chips: int,
    cost: Dict[str, float],
    hlo_text: str,
    model_flops: float,
    hw: HwSpec = V5E,
    pod_size: Optional[int] = None,
    memory_analysis: Optional[Dict[str, float]] = None,
) -> RooflineResult:
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    ops = C.parse_collectives(hlo_text, pod_size=pod_size)
    # ICI effective bandwidth: a ring all-reduce on a 2D-torus axis uses one link
    # pair per direction; we credit one link per op (conservative — no multi-axis
    # overlap), which keeps the estimate an upper bound on collective time.
    coll = C.collective_seconds(ops, ici_bw=hw.ici_link_bw, dcn_bw=hw.dcn_bw if pod_size else None)
    compute_s = flops / hw.peak_flops_bf16
    memory_s = nbytes / hw.hbm_bw
    collective_s = coll["total_s"]
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    step_s = max(terms.values())
    useful = model_flops / max(flops * chips, 1.0)
    return RooflineResult(
        arch=arch,
        shape=shape,
        mesh=mesh,
        chips=chips,
        flops_per_device=flops,
        bytes_per_device=nbytes,
        collective=coll,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        bottleneck=bottleneck,
        step_s=step_s,
        model_flops=model_flops,
        useful_fraction=useful,
        roofline_fraction=compute_s / step_s if step_s > 0 else 0.0,
        memory_analysis=memory_analysis,
    )


def extrapolate(v1: float, v2: float, L1: int, L2: int, L: int) -> float:
    """Linear-in-depth extrapolation: total(L) = f(L1) + slope·(L-L1)."""
    per = (v2 - v1) / (L2 - L1)
    return max(v1 + per * (L - L1), 0.0)


def extrapolate_cell(cost1, cost2, agg1, agg2, L1, L2, L):
    """Extrapolate a cost_analysis dict + per-kind collective aggregate in depth."""
    cost = {
        k: extrapolate(float(cost1.get(k, 0.0)), float(cost2.get(k, 0.0)), L1, L2, L)
        for k in set(cost1) | set(cost2)
        if isinstance(cost1.get(k, 0.0), (int, float)) and "{" not in k
    }
    kinds = set(agg1) | set(agg2)
    agg = {}
    for kind in kinds:
        z = {"count": 0.0, "bytes": 0.0, "wire_bytes": 0.0, "dcn_wire_bytes": 0.0}
        a1, a2 = agg1.get(kind, z), agg2.get(kind, z)
        agg[kind] = {f: extrapolate(a1[f], a2[f], L1, L2, L) for f in z}
    return cost, agg


def model_flops_for(cfg, shape, *, mode: str) -> float:
    """6·N_active·D for train, 2·N_active·D for inference (D = tokens processed)."""
    n_active = cfg.active_param_count()
    if mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch
