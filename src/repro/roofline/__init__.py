"""Roofline analysis from compiled (dry-run) artifacts — no hardware required."""
from repro.roofline.hw import V5E
from repro.roofline.collectives import parse_collectives, collective_seconds
from repro.roofline.model import roofline_terms, RooflineResult
