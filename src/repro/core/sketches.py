"""Randomized sketching operators (the paper's §II-§IV operator family).

Every sketch ``S ∈ R^{m×n}`` here satisfies ``E[SᵀS] = I_n`` — the normalization the
paper's theory (Lemmas 1-7) assumes. This module owns the *configuration* surface
(:class:`SketchSpec`), the leverage-score utilities, and a thin functional API; the
operators themselves — ``apply``/``adjoint``/``apply_blocked``/``materialize`` plus
the registry that replaced the old string if-chain — live in
:mod:`repro.core.operators`. ``spec.use_kernel`` routes through the Pallas TPU
kernels in ``repro.kernels`` where one exists (interpret-mode on CPU).

Supported kinds (paper section in brackets):
  * ``gaussian``       — i.i.d. N(0, 1/m)                                     [§III]
  * ``rademacher``     — i.i.d. ±1/√m signs (sub-gaussian, 1-bit RNG; beyond-paper,
                         same Thm-1-style averaging guarantees — arXiv:2412.20301)
  * ``srht``           — randomized Hadamard (ROS): sqrt(n/m)·P·(H/√n)·D      [§IV-A]
  * ``uniform``        — uniform row sampling, with/without replacement       [§IV-B]
  * ``leverage``       — leverage-score row sampling (exact or approximate)   [§IV-C]
  * ``sjlt``           — sparse JL / CountSketch with ``s`` nonzeros per col  [§IV-D]
  * ``hybrid``         — uniform-sample m' rows, then an inner sketch m'→m    [§IV-D]

Design notes
------------
* ``SketchSpec`` is a frozen, hashable config — safe as a static jit argument.
* Per-element randomness (Gaussian entries, SJLT rows, SRHT signs) is counter-based:
  a pure function of ``(key, global index)`` shared with the Pallas kernels, so
  blocked/streamed application reproduces one-shot application for any block size.
* To sketch ``A`` and ``b`` with the *same* S (as Algorithm 1 requires), concatenate
  ``[A, b[:, None]]`` before sketching: :func:`sketch_data` does this.
* SRHT pads n to the next power of two internally (zero rows of A contribute nothing;
  E[SᵀS] restricted to the first n coordinates is still I_n by exchangeability of the
  Hadamard/Rademacher construction).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------- spec

KINDS = ("gaussian", "rademacher", "srht", "uniform", "leverage", "sjlt", "hybrid")


@dataclasses.dataclass(frozen=True)
class SketchSpec:
    """Static description of a sketching operator.

    Attributes:
      kind: one of ``KINDS``.
      m: sketch dimension (rows of S).
      replacement: (uniform/leverage) sample with replacement. The paper's Lemma 5
        covers both; without-replacement has strictly smaller bias.
      s: (sjlt) nonzeros per column of S.
      m_prime: (hybrid) intermediate uniform-sampling dimension, m <= m_prime <= n.
      inner: (hybrid) kind of the second-stage sketch ("gaussian" or "sjlt").
      use_kernel: route through the Pallas TPU kernels in ``repro.kernels`` where one
        exists (interpret-mode on CPU).
    """

    kind: str
    m: int
    replacement: bool = True
    s: int = 4
    m_prime: int = 0
    inner: str = "gaussian"
    use_kernel: bool = False

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown sketch kind {self.kind!r}; expected one of {KINDS}")
        if self.m <= 0:
            raise ValueError("sketch dimension m must be positive")
        if self.kind == "hybrid":
            if self.m_prime < self.m:
                raise ValueError("hybrid sketch needs m_prime >= m")
            if self.inner not in ("gaussian", "rademacher", "sjlt", "srht"):
                raise ValueError(f"unsupported hybrid inner sketch {self.inner!r}")

    def apply(self, key: jax.Array, A: jax.Array) -> jax.Array:
        """Return ``S @ A`` where A has shape (n, ...)."""
        return apply_sketch(self, key, A)

    def operator(self, key: jax.Array, n: int, *, scores: Optional[jax.Array] = None):
        """The frozen :class:`repro.core.operators.SketchOp` for this spec."""
        from repro.core import operators

        return operators.make_operator(self, key, n, scores=scores)


# ----------------------------------------------------------------- hadamard utils


def _fwht(x: jax.Array) -> jax.Array:
    """In-place-style iterative fast Walsh-Hadamard transform along axis 0.

    x: (n, ...) with n a power of two. Returns H @ x with H the *unnormalized*
    ±1 Hadamard matrix (HᵀH = n·I).
    """
    n = x.shape[0]
    if n & (n - 1):
        raise ValueError(f"FWHT needs a power-of-two length, got {n}")
    h = 1
    while h < n:
        x = x.reshape(n // (2 * h), 2, h, *x.shape[1:])
        a = x[:, 0]
        b = x[:, 1]
        x = jnp.stack([a + b, a - b], axis=1).reshape(n, *x.shape[3:])
        h *= 2
    return x


def next_pow2(n: int) -> int:
    return 1 << (n - 1).bit_length()


# ------------------------------------------------------------------ leverage utils


def leverage_scores(
    A: jax.Array, *, method: str = "qr", key: Optional[jax.Array] = None
) -> jax.Array:
    """Row leverage scores ℓ_i = ‖ũ_i‖² of A (sums to rank(A) = d).

    ``key`` randomizes the sketched ``approx`` path (Drineas et al. 2012) — pass a
    per-worker key so approximate leverage sampling is i.i.d. across workers. The
    exact qr/svd paths are deterministic and ignore it.
    """
    if method == "svd":
        U, _, _ = jnp.linalg.svd(A, full_matrices=False)
        return jnp.sum(U * U, axis=1)
    if method == "qr":
        Q, _ = jnp.linalg.qr(A)
        return jnp.sum(Q * Q, axis=1)
    if method == "approx":
        # Beyond-paper: sketched leverage scores (Drineas et al. 2012): compute R from
        # a QR of an SRHT sketch of A, then ℓ̂_i = ‖a_iᵀ R⁻¹‖². O(nd log n + nd²) → O(nd·r).
        n, d = A.shape
        m = max(4 * d, 64)
        if m >= n:
            # Sketching to m >= n rows only loses information — exact is cheaper.
            return leverage_scores(A, method="qr")
        if key is None:
            key = jax.random.PRNGKey(0)
        SA = srht_sketch(key, A, m)
        _, R = jnp.linalg.qr(SA)
        AR = jax.scipy.linalg.solve_triangular(R.T, A.T, lower=True).T
        return jnp.sum(AR * AR, axis=1)
    raise ValueError(f"unknown leverage method {method!r}")


# ------------------------------------------------------- functional API (wrappers)
#
# Each kind function builds the matching SketchOp through the registry; they exist
# for callers that think in terms of one kind rather than a SketchSpec.


def gaussian_sketch(key: jax.Array, A: jax.Array, m: int, *, use_kernel: bool = False) -> jax.Array:
    """S with i.i.d. N(0, 1/m) entries. E[SᵀS] = I. Unbiased estimator (Lemma 1)."""
    return apply_sketch(SketchSpec("gaussian", m, use_kernel=use_kernel), key, A)


def rademacher_sketch(key: jax.Array, A: jax.Array, m: int, *, use_kernel: bool = False) -> jax.Array:
    """S with i.i.d. ±1/√m entries (packed counter signs). E[SᵀS] = I; sub-gaussian,
    so it inherits the Gaussian family's embedding/averaging guarantees at ~1/60th
    the RNG cost (one threefry word per 32 entries instead of threefry+Box-Muller
    per entry)."""
    return apply_sketch(SketchSpec("rademacher", m, use_kernel=use_kernel), key, A)


def srht_sketch(key: jax.Array, A: jax.Array, m: int, *, use_kernel: bool = False) -> jax.Array:
    """Randomized Hadamard (ROS) sketch: S = sqrt(n_pad/m) · P · (H/√n_pad) · D.

    P samples m of n_pad rows uniformly with replacement (matching the paper's
    Lemma 4 analysis, which assumes with-replacement sampling).
    """
    return apply_sketch(SketchSpec("srht", m, use_kernel=use_kernel), key, A)


def uniform_sketch(
    key: jax.Array, A: jax.Array, m: int, *, replacement: bool = True
) -> jax.Array:
    """Uniform row sampling, scaled so E[SᵀS] = I (each kept row × sqrt(n/m))."""
    return apply_sketch(SketchSpec("uniform", m, replacement=replacement), key, A)


def leverage_sketch(
    key: jax.Array,
    A: jax.Array,
    m: int,
    *,
    scores: Optional[jax.Array] = None,
) -> jax.Array:
    """Leverage-score sampling (paper §IV-C): P[row j] = ℓ_j / d, row scaled by
    1/sqrt(m·p_j) so that E[SᵀS] = I. Sampling is with replacement (Lemma 6)."""
    return apply_sketch(SketchSpec("leverage", m), key, A, scores=scores)


def sjlt_sketch(
    key: jax.Array, A: jax.Array, m: int, *, s: int = 4, use_kernel: bool = False
) -> jax.Array:
    """Sparse Johnson-Lindenstrauss transform [Nelson & Nguyên].

    Each column of S (i.e. each of the n input coordinates) gets ``s`` nonzeros,
    value ±1/√s, in buckets chosen uniformly: (SA)_r = Σ_{i: h(i)∋r} σ_i/√s · A_i.
    E[SᵀS] = I. s=1 is CountSketch.
    """
    return apply_sketch(SketchSpec("sjlt", m, s=s, use_kernel=use_kernel), key, A)


def hybrid_sketch(
    key: jax.Array,
    A: jax.Array,
    m: int,
    m_prime: int,
    *,
    inner: str = "gaussian",
    s: int = 4,
    use_kernel: bool = False,
) -> jax.Array:
    """Paper §IV-D: uniform-sample m' rows (the part a worker can afford to *read*),
    then sketch m' → m with a better sketch (the part it can afford to *compute*)."""
    spec = SketchSpec("hybrid", m, m_prime=m_prime, inner=inner, s=s, use_kernel=use_kernel)
    return apply_sketch(spec, key, A)


# --------------------------------------------------------------------------- dispatch


def apply_sketch(
    spec: SketchSpec,
    key: jax.Array,
    A: jax.Array,
    *,
    scores: Optional[jax.Array] = None,
) -> jax.Array:
    """Apply the sketch described by ``spec`` along axis 0 of A (registry dispatch)."""
    from repro.core import operators

    return operators.apply(spec, key, A, scores=scores)


def sketch_data(spec: SketchSpec, key: jax.Array, A: jax.Array, b: jax.Array):
    """Sketch (A, b) with the *same* S (Algorithm 1): returns (SA, Sb).

    b may be (n,) or (n, k) (multi-target least squares, e.g. one-hot labels)."""
    bm = b if b.ndim == 2 else b[:, None]
    d = A.shape[1]
    SAb = apply_sketch(spec, key, jnp.concatenate([A, bm], axis=1))
    Sb = SAb[:, d:]
    return SAb[:, :d], (Sb if b.ndim == 2 else Sb[:, 0])


def materialize(spec: SketchSpec, key: jax.Array, n: int, dtype=jnp.float32) -> jax.Array:
    """Materialize S ∈ R^{m×n} explicitly (tests / small problems only): S = S @ I."""
    return apply_sketch(spec, key, jnp.eye(n, dtype=dtype))
