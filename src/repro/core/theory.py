"""Closed-form predictions from the paper — used to *validate* the implementation.

Everything here is a pure function of problem dimensions, so tests and benchmarks can
compare Monte-Carlo estimates against the paper's exact formulas / bounds:

  * Lemma 1  : E[f(x̂)] − f(x*) = f(x*) · d/(m−d−1)          (single Gaussian sketch)
  * Theorem 1: E[f(x̄)] − f(x*) = f(x*) · d/(q(m−d−1))       (averaged, exact)
  * Lemma 2  : error(q) = variance/q + bias²·(q−1)/q          (any i.i.d. sketch)
  * Lemma 4/5/6 : bias bounds for ROS / uniform / leverage sketches
  * Lemma 7  : E‖x̂−x*‖² = f(x*)·(d−n)/(m−n−1)               (right sketch, n<d)
  * Eq. (5)  : I(S_kA; A)/(nd) ≤ (m/n)·log(2πeγ²)            (privacy)
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


# ------------------------------------------------------------------ exact (Gaussian)


def gaussian_single_error(m: int, d: int) -> float:
    """Lemma 1: relative expected error of one Gaussian-sketched solution."""
    if m <= d + 1:
        raise ValueError("Lemma 1 requires m > d + 1")
    return d / (m - d - 1)


def gaussian_averaged_error(m: int, d: int, q: int) -> float:
    """Theorem 1: relative expected error of the q-average (exact, unbiased)."""
    return gaussian_single_error(m, d) / q


def gaussian_least_norm_error(m: int, n: int, d: int) -> float:
    """Lemma 7: E‖x̂−x*‖²/f(x*) for the right sketch (n < d)."""
    if m <= n + 1:
        raise ValueError("Lemma 7 requires m > n + 1")
    return (d - n) / (m - n - 1)


def theorem1_success_probability(m: int, d: int, q: int, eps: float, c1: float = 0.1) -> float:
    """Theorem 1's lower bound on P[(f(x̄)−f(x*))/f(x*) ≤ ε/q]."""
    p_inv = 1.0 - math.exp(-c1 * m)
    factor = 1.0 - (1.0 / eps) * d / (m - d - 1)
    return max(0.0, p_inv**q * factor)


# ------------------------------------------------------------------ Lemma 2 pieces


def lemma2_error(variance: float, bias_sq: float, q: int) -> float:
    """E[f(x̄)] − f(x*) = variance/q + bias²·(q−1)/q."""
    return variance / q + bias_sq * (q - 1) / q


def empirical_bias_variance(Axhats: jax.Array, Axstar: jax.Array):
    """Monte-Carlo estimates of the Lemma-2 components from stacked predictions.

    Axhats: (trials, n) of A@x̂ samples; Axstar: (n,).
    Returns (variance_term, bias_sq_term):
      variance_term = E‖Ax̂ − Ax*‖²  (the 1/q coefficient)
      bias_sq_term  = ‖E[Ax̂] − Ax*‖² (the (q−1)/q coefficient)
    """
    diffs = Axhats - Axstar[None, :]
    variance_term = jnp.mean(jnp.sum(diffs * diffs, axis=1))
    mean_diff = jnp.mean(diffs, axis=0)
    bias_sq_term = jnp.sum(mean_diff * mean_diff)
    return variance_term, bias_sq_term


# ------------------------------------------------------------------ bias bounds


def ros_z_bound(m: int, d: int, fstar: float, min_row_leverage: float = 0.0) -> float:
    """Lemma 4: E‖z‖² ≤ (d/m)(1 − 2·min_i‖ũ_i‖²/d)·f(x*)."""
    return (d / m) * (1.0 - 2.0 * min_row_leverage / d) * fstar


def ros_bias_bound(eps: float, m: int, d: int, fstar: float) -> float:
    """Lemma 4 (eq. 9): ‖E[Ax̂] − Ax*‖ ≤ sqrt(4ε·(d/m)·f(x*))."""
    return math.sqrt(4.0 * eps * (d / m) * fstar)


def uniform_z_bound(
    m: int, n: int, fstar: float, max_row_leverage: float, *, replacement: bool = True
) -> float:
    """Lemma 5: E‖z‖² bounds for uniform sampling (with / without replacement)."""
    base = (n / m) * fstar * max_row_leverage
    if replacement:
        return base
    return base * (n - m) / (n - 1)


def uniform_bias_bound(
    eps: float, m: int, n: int, fstar: float, max_row_leverage: float, *, replacement: bool = True
) -> float:
    """Lemma 5 (eqs. 12-13)."""
    return math.sqrt(4.0 * eps * uniform_z_bound(m, n, fstar, max_row_leverage, replacement=replacement))


def leverage_z_bound(m: int, d: int, fstar: float) -> float:
    """Lemma 6: E‖z‖² ≤ (d/m)·f(x*)."""
    return (d / m) * fstar


def leverage_bias_bound(eps: float, m: int, d: int, fstar: float) -> float:
    """Lemma 6 (eq. 15)."""
    return math.sqrt(4.0 * eps * (d / m) * fstar)


def subspace_embedding_eps(U: jax.Array, S_applied_U: jax.Array) -> jax.Array:
    """Empirical ε such that (1−ε)I ⪯ (UᵀSᵀSU)⁻¹ ⪯ (1+ε)I (Lemma 3's assumption).

    Returns max(|eig((UᵀSᵀSU)⁻¹) − 1|).
    """
    G = S_applied_U.T @ S_applied_U
    w = jnp.linalg.eigvalsh(jnp.linalg.inv(G))
    return jnp.max(jnp.abs(w - 1.0))


# ------------------------------------------------------------------ required workers


def workers_for_error(m: int, d: int, eps: float) -> int:
    """Paper §I: #workers for target relative error ε scales as 1/ε (Gaussian)."""
    return max(1, math.ceil(gaussian_single_error(m, d) / eps))
