"""Distributed sketch-and-solve over a JAX mesh (the paper's Algorithm 1 at pod scale).

The q serverless workers become shards of one (or more) mesh axes. Each shard:
  1. derives its own key (fold_in worker index) — workers are stateless i.i.d. copies,
  2. sketches (A, b) → (S_kA, S_kb)   [master-sketch mode ships these; worker-sketch
     mode computes them from replicated/broadcast A],
  3. solves the m×d sub-problem locally,
  4. contributes to a masked psum average (stragglers contribute 0 and shrink the
     denominator — the estimator is Algorithm 1 with the realized q′).

Two data-placement regimes:
  * ``replicated``   — every worker sees all of A (the paper's setting; A replicated or
    broadcast once, privacy mode has the master do step 2).
  * ``row_sharded``  — beyond-paper: A is row-sharded across workers and each worker
    sketches only its own rows (sampling-family sketches restricted to the local block,
    scaled by the global n). The average is then over *local-block* estimators; this is
    the divide-and-conquer ("local sketching") regime — biased in general but it never
    moves raw rows across hosts, and for uniform-sampling sketches it is *identical in
    distribution* to global uniform sampling when rows are exchangeable.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import averaging, operators, sketches as sk, solve
from repro.utils import prng
from repro.utils.compat import shard_map


_worker_index = averaging.worker_index


def distributed_sketch_solve(
    mesh: Mesh,
    spec: sk.SketchSpec,
    key: jax.Array,
    A: jax.Array,
    b: jax.Array,
    *,
    axis_names: tuple = ("data",),
    reg: float = 0.0,
    method: str = "fused",
    straggler_mask: Optional[jax.Array] = None,
    row_sharded: bool = False,
    round_id: int = 0,
):
    """Algorithm 1 over ``mesh``: one sketch-and-solve worker per shard of axis_names.

    Each worker takes the fused single-pass sketch→Gram path by default
    (``method="fused"`` in :func:`repro.core.solve.sketch_and_solve`): it streams
    its (G_k, c_k) out of one pass over the local copy of [A | b] and solves d×d,
    never materializing S_kA. Pass ``method="qr"`` for the two-pass reference.

    Args:
      straggler_mask: optional (q,) float mask of which workers made the deadline
        (1=arrived). None = all arrived.
      row_sharded: shard A's rows over the worker axes instead of replicating.
    Returns:
      x̄ (d,), replicated.
    """
    q = 1
    for name in axis_names:
        q *= mesh.shape[name]
    if straggler_mask is None:
        straggler_mask = jnp.ones((q,), jnp.float32)

    a_spec = P(axis_names) if row_sharded else P()
    in_specs = (P(), a_spec, P(), P())
    out_specs = P()

    def worker(key, A_blk, b_blk, mask_all):
        widx = _worker_index(axis_names)
        wkey = prng.worker_key(key, widx, round_id)
        xk = solve.sketch_and_solve(spec, wkey, A_blk, b_blk, reg=reg, method=method)
        mask = mask_all[widx]
        num = jax.lax.psum(xk * mask, axis_names)
        den = jax.lax.psum(mask, axis_names)
        return num / jnp.maximum(den, 1.0)

    fn = shard_map(worker, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    return fn(key, A, b, straggler_mask)


def distributed_sketch_solve_master(
    mesh: Mesh,
    spec: sk.SketchSpec,
    key: jax.Array,
    A: jax.Array,
    b: jax.Array,
    *,
    axis_names: tuple = ("data",),
    reg: float = 0.0,
    method: str = "fused",
    straggler_mask: Optional[jax.Array] = None,
    round_id: int = 0,
):
    """Algorithm 1 in *master-sketch* mode (the paper's privacy deployment: only the
    master touches raw rows; workers see only sketch products).

    ``method="fused"`` (default): the master streams all q fused Grams
    ``(G_k, c_k)`` in one mesh-parallel batched pass over [A | b]
    (``operators.gram_batched`` — S_kA never materialized), ships O(d²) per worker
    instead of O(m·d), and each worker's solve is a d×d Cholesky. Any other
    ``method`` keeps the two-pass reference: batch-materialize (S_kA, S_kb) via
    ``operators.sketch_data_batched`` and factorize per worker. Worker keys match
    :func:`distributed_sketch_solve`, so the two modes return the same x̄ for the
    same inputs (up to the solver's float tolerance).
    """
    q = 1
    for name in axis_names:
        q *= mesh.shape[name]
    if straggler_mask is None:
        straggler_mask = jnp.ones((q,), jnp.float32)

    keys = prng.worker_keys(key, q, round_id)

    if method == "fused":
        Gs, cs = operators.gram_batched(
            spec, keys, A, b, mesh=mesh, axis_names=axis_names
        )  # (q, d, d), (q, d[, k])

        def worker_fused(G_blk, c_blk, mask_all):
            widx = _worker_index(axis_names)
            xk = solve.lstsq_gram(G_blk[0], c_blk[0], reg=reg)
            mask = mask_all[widx]
            num = jax.lax.psum(xk * mask, axis_names)
            den = jax.lax.psum(mask, axis_names)
            return num / jnp.maximum(den, 1.0)

        fn = shard_map(
            worker_fused,
            mesh=mesh,
            in_specs=(P(axis_names), P(axis_names), P()),
            out_specs=P(),
        )
        return fn(Gs, cs, straggler_mask)

    SA, Sb = operators.sketch_data_batched(
        spec, keys, A, b, mesh=mesh, axis_names=axis_names
    )  # (q, m, d), (q, m[, k])

    def worker(SA_blk, Sb_blk, mask_all):
        widx = _worker_index(axis_names)
        xk = solve.lstsq(SA_blk[0], Sb_blk[0], reg=reg, method=method)
        mask = mask_all[widx]
        num = jax.lax.psum(xk * mask, axis_names)
        den = jax.lax.psum(mask, axis_names)
        return num / jnp.maximum(den, 1.0)

    fn = shard_map(
        worker,
        mesh=mesh,
        in_specs=(P(axis_names), P(axis_names), P()),
        out_specs=P(),
    )
    return fn(SA, Sb, straggler_mask)


def distributed_sketch_least_norm(
    mesh: Mesh,
    spec: sk.SketchSpec,
    key: jax.Array,
    A: jax.Array,
    b: jax.Array,
    *,
    axis_names: tuple = ("data",),
    straggler_mask: Optional[jax.Array] = None,
    round_id: int = 0,
):
    """§V right-sketch averaging over the mesh (n < d). A replicated."""
    q = 1
    for name in axis_names:
        q *= mesh.shape[name]
    if straggler_mask is None:
        straggler_mask = jnp.ones((q,), jnp.float32)

    def worker(key, A_rep, b_rep, mask_all):
        widx = _worker_index(axis_names)
        wkey = prng.worker_key(key, widx, round_id)
        xk = solve.sketch_least_norm(spec, wkey, A_rep, b_rep)
        mask = mask_all[widx]
        num = jax.lax.psum(xk * mask, axis_names)
        den = jax.lax.psum(mask, axis_names)
        return num / jnp.maximum(den, 1.0)

    fn = shard_map(worker, mesh=mesh, in_specs=(P(), P(), P(), P()), out_specs=P())
    return fn(key, A, b, straggler_mask)


def distributed_sketch_solve_multiround(
    mesh: Mesh,
    spec: sk.SketchSpec,
    key: jax.Array,
    A: jax.Array,
    b: jax.Array,
    *,
    rounds: int,
    axis_names: tuple = ("data",),
    reg: float = 0.0,
):
    """Elastic scaling in time: run Algorithm 1 for ``rounds`` successive waves of
    workers and average everything (effective q = rounds × mesh workers). Each wave
    reuses the same devices but fresh i.i.d. sketches — exactly how the serverless
    deployment keeps invoking new lambdas until the error target is met.

    Each round folds its id into the worker keys, so round r is a fresh i.i.d. batch.
    """
    acc = None
    for r in range(rounds):
        xbar_r = distributed_sketch_solve(
            mesh, spec, key, A, b, axis_names=axis_names, reg=reg, round_id=r
        )
        acc = xbar_r if acc is None else acc + (xbar_r - acc) / (r + 1.0)
    return acc
