"""Distributed sketch-and-solve over a JAX mesh (the paper's Algorithm 1 at pod scale).

The q serverless workers become shards of one (or more) mesh axes. Each shard:
  1. derives its own key (fold_in worker index) — workers are stateless i.i.d. copies,
  2. sketches (A, b) → (S_kA, S_kb)   [master-sketch mode ships these; worker-sketch
     mode computes them from replicated/broadcast A],
  3. solves the m×d sub-problem locally,
  4. contributes to a masked psum average (stragglers contribute 0 and shrink the
     denominator — the estimator is Algorithm 1 with the realized q′).

Two data-placement regimes:
  * ``replicated``   — every worker sees all of A (the paper's setting; A replicated or
    broadcast once, privacy mode has the master do step 2).
  * ``row_sharded``  — beyond-paper: A is row-sharded across workers and each worker
    sketches only its own rows (sampling-family sketches restricted to the local block,
    scaled by the global n). The average is then over *local-block* estimators; this is
    the divide-and-conquer ("local sketching") regime — biased in general but it never
    moves raw rows across hosts, and for uniform-sampling sketches it is *identical in
    distribution* to global uniform sampling when rows are exchangeable.

All-straggler contract (shared by every solve variant here): a *concrete* mask with
zero survivors raises ``ValueError`` eagerly — an empty round has no estimator and is
a caller bug; a *traced* mask (the mask computed inside a jitted step) NaN-poisons x̄
by default, with ``on_empty="zero"`` restoring the legacy silent x̄ = 0.

These mesh drivers are the *synchronous idealization* — every worker launches at
once and the mask is known up front. The asynchronous reality (arrival order,
deadlines, retries, early stopping) lives in :mod:`repro.runtime`;
:func:`distributed_sketch_solve_multiround` delegates to it when given a
``latency`` model.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import averaging, operators, sketches as sk, solve
from repro.utils import prng
from repro.utils.compat import shard_map


_worker_index = averaging.worker_index

# Incremented each time the multiround worker body is traced; tests assert the
# jitted closure is hoisted out of the round loop (one trace per call, not per round).
MULTIROUND_TRACE_COUNT = 0


def _mesh_workers(mesh: Mesh, axis_names: tuple) -> int:
    q = 1
    for name in axis_names:
        q *= mesh.shape[name]
    return q


def _checked_mask(straggler_mask: Optional[jax.Array], q: int) -> jax.Array:
    """Default / validate the straggler mask; raise eagerly on a concrete empty round."""
    if straggler_mask is None:
        return jnp.ones((q,), jnp.float32)
    if not isinstance(straggler_mask, jax.core.Tracer):
        arr = np.asarray(straggler_mask)
        if arr.sum() == 0:
            raise ValueError(
                "straggler_mask has no surviving workers (q' = 0): the Algorithm-1 "
                "average over an empty set is undefined. Loosen the deadline or "
                "resubmit the round (see repro.runtime for retries)."
            )
    return straggler_mask


def distributed_sketch_solve(
    mesh: Mesh,
    spec: sk.SketchSpec,
    key: jax.Array,
    A: jax.Array,
    b: jax.Array,
    *,
    axis_names: tuple = ("data",),
    reg: float = 0.0,
    method: str = "fused",
    straggler_mask: Optional[jax.Array] = None,
    row_sharded: bool = False,
    round_id: int = 0,
    on_empty: str = "nan",
):
    """Algorithm 1 over ``mesh``: one sketch-and-solve worker per shard of axis_names.

    Each worker takes the fused single-pass sketch→Gram path by default
    (``method="fused"`` in :func:`repro.core.solve.sketch_and_solve`): it streams
    its (G_k, c_k) out of one pass over the local copy of [A | b] and solves d×d,
    never materializing S_kA. Pass ``method="qr"`` for the two-pass reference.

    Args:
      straggler_mask: optional (q,) float mask of which workers made the deadline
        (1=arrived). None = all arrived. A concrete all-zero mask raises eagerly.
      row_sharded: shard A's rows over the worker axes instead of replicating.
      on_empty: traced-mask q'=0 behavior — ``"nan"`` (default) or ``"zero"``.
    Returns:
      x̄ (d,), replicated.
    """
    q = _mesh_workers(mesh, axis_names)
    straggler_mask = _checked_mask(straggler_mask, q)

    a_spec = P(axis_names) if row_sharded else P()
    in_specs = (P(), a_spec, P(), P())
    out_specs = P()

    def worker(key, A_blk, b_blk, mask_all):
        widx = _worker_index(axis_names)
        wkey = prng.worker_key(key, widx, round_id)
        xk = solve.sketch_and_solve(spec, wkey, A_blk, b_blk, reg=reg, method=method)
        return averaging.psum_average(xk, mask_all[widx], axis_names, on_empty=on_empty)

    fn = shard_map(worker, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    return fn(key, A, b, straggler_mask)


def distributed_sketch_solve_master(
    mesh: Mesh,
    spec: sk.SketchSpec,
    key: jax.Array,
    A: jax.Array,
    b: jax.Array,
    *,
    axis_names: tuple = ("data",),
    reg: float = 0.0,
    method: str = "fused",
    straggler_mask: Optional[jax.Array] = None,
    round_id: int = 0,
    on_empty: str = "nan",
):
    """Algorithm 1 in *master-sketch* mode (the paper's privacy deployment: only the
    master touches raw rows; workers see only sketch products).

    ``method="fused"`` (default): the master streams all q fused Grams
    ``(G_k, c_k)`` in one mesh-parallel batched pass over [A | b]
    (``operators.gram_batched`` — S_kA never materialized), ships O(d²) per worker
    instead of O(m·d), and each worker's solve is a d×d Cholesky. When
    ``spec.use_kernel`` is set and no real mesh shards the keys, that batched pass
    is ONE multi-worker Pallas launch (``SketchOp.gram_batched_kernel``) reading A
    once for all q sketches, rather than q kernel launches. Any other
    ``method`` keeps the two-pass reference: batch-materialize (S_kA, S_kb) via
    ``operators.sketch_data_batched`` and factorize per worker. Worker keys match
    :func:`distributed_sketch_solve`, so the two modes return the same x̄ for the
    same inputs (up to the solver's float tolerance).
    """
    q = _mesh_workers(mesh, axis_names)
    straggler_mask = _checked_mask(straggler_mask, q)

    keys = prng.worker_keys(key, q, round_id)

    if method == "fused":
        Gs, cs = operators.gram_batched(
            spec, keys, A, b, mesh=mesh, axis_names=axis_names
        )  # (q, d, d), (q, d[, k])

        def worker_fused(G_blk, c_blk, mask_all):
            widx = _worker_index(axis_names)
            xk = solve.lstsq_gram(G_blk[0], c_blk[0], reg=reg)
            return averaging.psum_average(
                xk, mask_all[widx], axis_names, on_empty=on_empty
            )

        fn = shard_map(
            worker_fused,
            mesh=mesh,
            in_specs=(P(axis_names), P(axis_names), P()),
            out_specs=P(),
        )
        return fn(Gs, cs, straggler_mask)

    SA, Sb = operators.sketch_data_batched(
        spec, keys, A, b, mesh=mesh, axis_names=axis_names
    )  # (q, m, d), (q, m[, k])

    def worker(SA_blk, Sb_blk, mask_all):
        widx = _worker_index(axis_names)
        xk = solve.lstsq(SA_blk[0], Sb_blk[0], reg=reg, method=method)
        return averaging.psum_average(xk, mask_all[widx], axis_names, on_empty=on_empty)

    fn = shard_map(
        worker,
        mesh=mesh,
        in_specs=(P(axis_names), P(axis_names), P()),
        out_specs=P(),
    )
    return fn(SA, Sb, straggler_mask)


def distributed_sketch_least_norm(
    mesh: Mesh,
    spec: sk.SketchSpec,
    key: jax.Array,
    A: jax.Array,
    b: jax.Array,
    *,
    axis_names: tuple = ("data",),
    straggler_mask: Optional[jax.Array] = None,
    round_id: int = 0,
    on_empty: str = "nan",
):
    """§V right-sketch averaging over the mesh (n < d). A replicated."""
    q = _mesh_workers(mesh, axis_names)
    straggler_mask = _checked_mask(straggler_mask, q)

    def worker(key, A_rep, b_rep, mask_all):
        widx = _worker_index(axis_names)
        wkey = prng.worker_key(key, widx, round_id)
        xk = solve.sketch_least_norm(spec, wkey, A_rep, b_rep)
        return averaging.psum_average(xk, mask_all[widx], axis_names, on_empty=on_empty)

    fn = shard_map(worker, mesh=mesh, in_specs=(P(), P(), P(), P()), out_specs=P())
    return fn(key, A, b, straggler_mask)


def _multiround_fn(mesh, spec, axis_names, reg, method, on_empty):
    """The per-round mesh program with ``round_id`` as a *traced* argument, jitted
    once — successive rounds are executions, not retraces."""

    def worker(key, A_rep, b_rep, mask_all, round_arr):
        global MULTIROUND_TRACE_COUNT
        MULTIROUND_TRACE_COUNT += 1  # Python side effect: fires once per trace
        widx = _worker_index(axis_names)
        wkey = prng.worker_key(key, widx, round_arr)
        xk = solve.sketch_and_solve(spec, wkey, A_rep, b_rep, reg=reg, method=method)
        return averaging.psum_average(xk, mask_all[widx], axis_names, on_empty=on_empty)

    fn = shard_map(
        worker, mesh=mesh, in_specs=(P(), P(), P(), P(), P()), out_specs=P()
    )
    return jax.jit(fn)


def distributed_sketch_solve_multiround(
    mesh: Mesh,
    spec: sk.SketchSpec,
    key: jax.Array,
    A: jax.Array,
    b: jax.Array,
    *,
    rounds: int,
    axis_names: tuple = ("data",),
    reg: float = 0.0,
    method: str = "fused",
    on_empty: str = "nan",
    latency=None,
    runtime_config=None,
    error_fn=None,
):
    """Elastic scaling in time: run Algorithm 1 for ``rounds`` successive waves of
    workers and average everything (effective q = rounds × mesh workers). Each wave
    reuses the same devices but fresh i.i.d. sketches — exactly how the serverless
    deployment keeps invoking new lambdas until the error target is met.

    Each round folds its id into the worker keys, so round r is a fresh i.i.d. batch.
    The round id is a *traced* scalar of one jitted mesh program, so the loop
    executes ``rounds`` times but traces once (``MULTIROUND_TRACE_COUNT`` audits
    this).

    Asynchronous mode: pass a :class:`repro.runtime.LatencyModel` as ``latency``
    (optionally a :class:`repro.runtime.RuntimeConfig` and an ``error_fn`` —
    ``"theory"`` / ``"probe"`` / callable) and the call becomes a thin wrapper over
    :func:`repro.runtime.serverless_sketch_solve`: the same (worker, round) key
    grid, but arrival-ordered streaming averaging, deadlines, retries, and early
    stopping instead of the synchronous wave barrier. Returns x̄ either way.
    """
    q = _mesh_workers(mesh, axis_names)
    if latency is not None:
        from repro import runtime as rt

        res = rt.serverless_sketch_solve(
            spec, key, A, b, q=q, rounds=rounds, latency=latency,
            config=runtime_config, reg=reg, method=method, error_fn=error_fn,
        )
        return jnp.asarray(res.xbar, dtype=A.dtype)

    fn = _multiround_fn(mesh, spec, axis_names, reg, method, on_empty)
    mask = jnp.ones((q,), jnp.float32)
    acc = None
    for r in range(rounds):
        xbar_r = fn(key, A, b, mask, jnp.int32(r))
        acc = xbar_r if acc is None else acc + (xbar_r - acc) / (r + 1.0)
    return acc
