"""Local least-squares / least-norm solvers and sketch-and-solve (Algorithm 1 worker).

The worker-side problem is tiny (m×d with m = O(d)), so direct dense factorizations are
the right tool; CG is provided for the ill-conditioned / regularized path and as the
building block of the iterative-Hessian-sketch baseline.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import operators, sketches as sk


# --------------------------------------------------------------------------- direct


def lstsq(A: jax.Array, b: jax.Array, *, reg: float = 0.0, method: str = "qr") -> jax.Array:
    """argmin_x ‖Ax − b‖² + reg·‖x‖², A: (n, d), b: (n,) or (n, k)."""
    if method == "qr":
        if reg > 0.0:
            d = A.shape[1]
            A_aug = jnp.concatenate([A, jnp.sqrt(reg) * jnp.eye(d, dtype=A.dtype)], axis=0)
            b_aug = jnp.concatenate(
                [b, jnp.zeros((d,) + b.shape[1:], dtype=b.dtype)], axis=0
            )
            A, b = A_aug, b_aug
        Q, R = jnp.linalg.qr(A)
        return jax.scipy.linalg.solve_triangular(R, Q.T @ b, lower=False)
    if method == "chol":
        d = A.shape[1]
        G = A.T @ A + reg * jnp.eye(d, dtype=A.dtype)
        c = A.T @ b
        L = jnp.linalg.cholesky(G)
        y = jax.scipy.linalg.solve_triangular(L, c, lower=True)
        return jax.scipy.linalg.solve_triangular(L.T, y, lower=False)
    if method == "cg":
        return _cg_normal(A, b, reg=reg)
    raise ValueError(f"unknown method {method!r}")


def _cg_normal(A: jax.Array, b: jax.Array, *, reg: float = 0.0, iters: int = 64) -> jax.Array:
    """CG on the normal equations (AᵀA + reg·I)x = Aᵀb. Matrix-free."""

    def mv(x):
        return A.T @ (A @ x) + reg * x

    rhs = A.T @ b
    x0 = jnp.zeros_like(rhs)

    def body(_, state):
        x, r, p, rs = state
        Ap = mv(p)
        alpha = rs / (jnp.vdot(p, Ap) + 1e-30)
        x = x + alpha * p
        r = r - alpha * Ap
        rs_new = jnp.vdot(r, r)
        p = r + (rs_new / (rs + 1e-30)) * p
        return x, r, p, rs_new

    r0 = rhs - mv(x0)
    state = (x0, r0, r0, jnp.vdot(r0, r0))
    x, *_ = jax.lax.fori_loop(0, iters, body, state)
    return x


def lstsq_gram(G: jax.Array, c: jax.Array, *, reg: float = 0.0) -> jax.Array:
    """Solve ``(G + reg·I) x = c`` by Cholesky — the tiny d×d tail of the fused path.

    ``(G, c) = ((SA)ᵀ(SA), (SA)ᵀ(Sb))`` come out of one streamed sketch→Gram pass
    (:meth:`repro.core.operators.SketchOp.gram_blocked`); nothing here ever sees SA.
    """
    d = G.shape[0]
    L = jnp.linalg.cholesky(G + reg * jnp.eye(d, dtype=G.dtype))
    y = jax.scipy.linalg.solve_triangular(L, c, lower=True)
    return jax.scipy.linalg.solve_triangular(L.T, y, lower=False)


def least_norm(A: jax.Array, b: jax.Array) -> jax.Array:
    """min ‖x‖² s.t. Ax = b (n < d, full row rank): x = Aᵀ(AAᵀ)⁻¹b."""
    G = A @ A.T
    L = jnp.linalg.cholesky(G)
    y = jax.scipy.linalg.solve_triangular(L, b, lower=True)
    z = jax.scipy.linalg.solve_triangular(L.T, y, lower=False)
    return A.T @ z


# ----------------------------------------------------------------- sketch-and-solve


def sketch_and_solve(
    spec: sk.SketchSpec,
    key: jax.Array,
    A: jax.Array,
    b: jax.Array,
    *,
    reg: float = 0.0,
    method: str = "fused",
    block_rows: int = operators.DEFAULT_BLOCK_ROWS,
) -> jax.Array:
    """One worker of Algorithm 1 (left sketch, n > d):
    x̂ = argmin_x ‖S(Ax − b)‖² with S ~ spec.

    ``method="fused"`` (default) takes the single-pass sketch→Gram fast path:
    ``(G, c)`` accumulate in one streamed pass over ``[A | b]`` — SA is never
    materialized — and the solve is a d×d Cholesky. The two-pass paths
    (``"qr"``/``"chol"``/``"cg"``: materialize (SA, Sb), then factorize) are
    retained as the reference oracle.
    """
    if method == "fused":
        G, c = operators.gram_blocked(spec, key, A, b, block_rows=block_rows)
        return lstsq_gram(G, c, reg=reg)
    SA, Sb = sk.sketch_data(spec, key, A, b)
    return lstsq(SA, Sb, reg=reg, method=method)


def sketch_least_norm(
    spec: sk.SketchSpec,
    key: jax.Array,
    A: jax.Array,
    b: jax.Array,
) -> jax.Array:
    """One worker of the right-sketch least-norm problem (§V, n < d):
    ẑ = argmin ‖z‖² s.t. (ASᵀ)z = b;  x̂ = Sᵀẑ.

    S never exists in memory: ``ASᵀ = (S Aᵀ)ᵀ`` is one forward application of the
    operator to Aᵀ, and ``Sᵀẑ`` is its adjoint — a scatter for sampling sketches, an
    inverse-transform for SRHT, streamed counter-RNG tiles for Gaussian.
    """
    d = A.shape[1]
    # Data-independent right sketches only; a leverage right-sketch of I_d is uniform.
    scores = jnp.ones((d,), A.dtype) if spec.kind == "leverage" else None
    op = operators.make_operator(spec, key, d, scores=scores)
    SAt = op.apply(A.T)  # (m, n) = S @ Aᵀ
    z = least_norm(SAt.T, b)  # (m,) or (m, k)
    return op.adjoint(z)


def residual_cost(A: jax.Array, b: jax.Array, x: jax.Array) -> jax.Array:
    """f(x) = ‖Ax − b‖²."""
    r = A @ x - b
    return jnp.vdot(r, r).real


def relative_error(A, b, x, fstar) -> jax.Array:
    """(f(x) − f(x*)) / f(x*) — the paper's 'approximation error'."""
    return (residual_cost(A, b, x) - fstar) / fstar
