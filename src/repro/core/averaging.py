"""Master-side model averaging (Algorithm 1) with straggler resilience.

The paper's key systems claim is that because workers are i.i.d., the master may
average *whatever subset has arrived* — the estimator is unchanged with the realized
worker count q' ≤ q (Lemma 2 applies verbatim with q'). We express that as a masked
mean so the same code runs: (a) locally over a stacked (q, d) array, (b) inside
shard_map with ``jax.lax.psum`` over the worker mesh axis, (c) incrementally as a
streaming average when outputs trickle in (the serverless mode).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.utils import compat


def worker_index(axis_names) -> jax.Array:
    """Linear worker index across (possibly multiple) mesh axes, inside shard_map.

    The one definition shared by the solver, gradient-compression, and sketch-DP
    paths — their worker keys must agree, so their index arithmetic must too.
    """
    idx = jnp.int32(0)
    for name in axis_names:
        idx = idx * compat.axis_size(name) + jax.lax.axis_index(name)
    return idx


def masked_average(xs: jax.Array, mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean over axis 0 of xs (q, ...), counting only mask==1 rows.

    With mask=None this is the plain Algorithm-1 average. xs may have any rank
    (multi-output solutions stack as (q, d, k)): the mask broadcasts on axis 0.
    """
    if mask is None:
        return jnp.mean(xs, axis=0)
    m = mask.astype(xs.dtype).reshape((xs.shape[0],) + (1,) * (xs.ndim - 1))
    denom = jnp.maximum(jnp.sum(mask.astype(xs.dtype)), 1.0)
    return jnp.sum(xs * m, axis=0) / denom


def psum_average(x_local: jax.Array, mask_local: jax.Array, axis_name) -> jax.Array:
    """Straggler-resilient average across a mesh axis (inside shard_map).

    Workers that missed the deadline pass mask_local=0; their x_local is ignored and
    the denominator is the realized worker count.
    """
    num = jax.lax.psum(x_local * mask_local, axis_name)
    den = jax.lax.psum(mask_local, axis_name)
    return num / jnp.maximum(den, 1.0)


@dataclasses.dataclass
class StreamingAverage:
    """Incremental master: absorb worker outputs as they arrive (serverless mode).

    Tracks the running mean and count; ``state`` is a pytree so it can live on-device.
    """

    mean: jax.Array
    count: jax.Array

    @classmethod
    def init(cls, d: int, dtype=jnp.float32) -> "StreamingAverage":
        return cls(mean=jnp.zeros((d,), dtype), count=jnp.zeros((), dtype))

    def update(self, x: jax.Array) -> "StreamingAverage":
        c = self.count + 1.0
        return StreamingAverage(mean=self.mean + (x - self.mean) / c, count=c)


jax.tree_util.register_pytree_node(
    StreamingAverage,
    lambda s: ((s.mean, s.count), None),
    lambda _, c: StreamingAverage(*c),
)


def simulate_straggler_mask(
    key: jax.Array, q: int, *, drop_prob: float = 0.0, deadline_quantile: float = 1.0
) -> jax.Array:
    """Simulate which of q workers made the deadline.

    drop_prob models hard failures (lambda never returns); deadline_quantile models a
    latency cutoff: worker runtimes ~ LogNormal and only the fastest fraction count.
    Returns a float mask (q,) with 1.0 = arrived.
    """
    kd, kt = jax.random.split(key)
    alive = jax.random.bernoulli(kd, 1.0 - drop_prob, (q,))
    if deadline_quantile >= 1.0:
        return alive.astype(jnp.float32)
    t = jax.random.lognormal(kt, shape=(q,))
    cutoff = jnp.quantile(t, deadline_quantile)
    return (alive & (t <= cutoff)).astype(jnp.float32)
