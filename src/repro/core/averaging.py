"""Master-side model averaging (Algorithm 1) with straggler resilience.

The paper's key systems claim is that because workers are i.i.d., the master may
average *whatever subset has arrived* — the estimator is unchanged with the realized
worker count q' ≤ q (Lemma 2 applies verbatim with q'). We express that as a masked
mean so the same code runs: (a) locally over a stacked (q, d) array, (b) inside
shard_map with ``jax.lax.psum`` over the worker mesh axis, (c) incrementally as a
streaming average when outputs trickle in (the serverless mode).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.utils import compat


def worker_index(axis_names) -> jax.Array:
    """Linear worker index across (possibly multiple) mesh axes, inside shard_map.

    The one definition shared by the solver, gradient-compression, and sketch-DP
    paths — their worker keys must agree, so their index arithmetic must too.
    """
    idx = jnp.int32(0)
    for name in axis_names:
        idx = idx * compat.axis_size(name) + jax.lax.axis_index(name)
    return idx


def _guard_empty(avg: jax.Array, den: jax.Array, on_empty: str) -> jax.Array:
    """Define x̄ when *no* worker made the deadline (den == 0).

    ``"nan"`` (default): NaN-poison the average — an all-straggler round has no
    estimator (Algorithm 1's q′ = 0), and silently returning 0 used to masquerade
    as a perfectly converged solution downstream. ``"zero"`` restores the legacy
    x̄ = 0 for callers that treat an empty round as a no-op contribution.
    """
    if on_empty == "zero":
        return avg
    if on_empty == "nan":
        return jnp.where(den > 0, avg, jnp.nan)
    raise ValueError(f"on_empty must be 'nan' or 'zero', got {on_empty!r}")


def masked_average(
    xs: jax.Array, mask: Optional[jax.Array] = None, *, on_empty: str = "nan"
) -> jax.Array:
    """Mean over axis 0 of xs (q, ...), counting only mask==1 rows.

    With mask=None this is the plain Algorithm-1 average. xs may have any rank
    (multi-output solutions stack as (q, d, k)): the mask broadcasts on axis 0.
    An all-zero mask yields NaN by default (``on_empty`` — see :func:`_guard_empty`).
    """
    if mask is None:
        return jnp.mean(xs, axis=0)
    m = mask.astype(xs.dtype).reshape((xs.shape[0],) + (1,) * (xs.ndim - 1))
    den = jnp.sum(mask.astype(xs.dtype))
    avg = jnp.sum(xs * m, axis=0) / jnp.maximum(den, 1.0)
    return _guard_empty(avg, den, on_empty)


def psum_average(
    x_local: jax.Array, mask_local: jax.Array, axis_name, *, on_empty: str = "nan"
) -> jax.Array:
    """Straggler-resilient average across one or more mesh axes (inside shard_map).

    Workers that missed the deadline pass mask_local=0; their x_local is ignored and
    the denominator is the realized worker count. When *every* worker missed, the
    result follows ``on_empty`` (NaN-poison by default — see :func:`_guard_empty`;
    eager drivers in ``core.distributed`` raise before tracing instead).
    """
    num = jax.lax.psum(x_local * mask_local, axis_name)
    den = jax.lax.psum(mask_local, axis_name)
    avg = num / jnp.maximum(den, 1.0)
    return _guard_empty(avg, den, on_empty)


@dataclasses.dataclass
class StreamingAverage:
    """Incremental master: absorb worker outputs as they arrive (serverless mode).

    Tracks the running mean and count; ``state`` is a pytree so it can live on-device.
    """

    mean: jax.Array
    count: jax.Array

    @classmethod
    def init(cls, d: int, dtype=jnp.float32) -> "StreamingAverage":
        return cls(mean=jnp.zeros((d,), dtype), count=jnp.zeros((), dtype))

    def update(self, x: jax.Array) -> "StreamingAverage":
        c = self.count + 1.0
        return StreamingAverage(mean=self.mean + (x - self.mean) / c, count=c)


jax.tree_util.register_pytree_node(
    StreamingAverage,
    lambda s: ((s.mean, s.count), None),
    lambda _, c: StreamingAverage(*c),
)


def simulate_straggler_mask(
    key: jax.Array, q: int, *, drop_prob: float = 0.0, deadline_quantile: float = 1.0
) -> jax.Array:
    """Simulate which of q workers made the deadline.

    drop_prob models hard failures (lambda never returns); deadline_quantile models a
    latency cutoff: worker runtimes ~ LogNormal and only the fastest fraction count.
    Returns a float mask (q,) with 1.0 = arrived.
    """
    kd, kt = jax.random.split(key)
    alive = jax.random.bernoulli(kd, 1.0 - drop_prob, (q,))
    if deadline_quantile >= 1.0:
        return alive.astype(jnp.float32)
    t = jax.random.lognormal(kt, shape=(q,))
    cutoff = jnp.quantile(t, deadline_quantile)
    return (alive & (t <= cutoff)).astype(jnp.float32)
