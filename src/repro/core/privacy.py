"""Privacy accounting for distributed sketching (paper §III-A, Eq. 5).

When the master sketches locally and ships only ``(S_kA, S_kb)``, the information a
worker (or an eavesdropper on the worker link) sees about A is bounded by

    I(S_kA; A) / (nd)  ≤  (m/n) · log(2πeγ²)        [nats per matrix entry]

for A drawn entrywise from any distribution with variance γ². The framework exposes
this as an *accountant*: every sketched shipment registers (m, n, γ) and the report
aggregates the per-entry leakage across workers/rounds (mutual information is additive
across independent sketches of the same data in the worst case).
"""
from __future__ import annotations

import dataclasses
import math
from typing import List


def mi_per_entry_bound(m: int, n: int, gamma: float = 1.0) -> float:
    """Eq. (5): nats of mutual information per entry of A leaked by one sketch."""
    if m <= 0 or n <= 0:
        raise ValueError("m, n must be positive")
    return (m / n) * math.log(2.0 * math.pi * math.e * gamma * gamma)


def sketch_dim_for_privacy(n: int, budget_nats_per_entry: float, gamma: float = 1.0) -> int:
    """Largest sketch size m whose Eq.-(5) bound stays within the budget."""
    denom = math.log(2.0 * math.pi * math.e * gamma * gamma)
    return max(1, int(budget_nats_per_entry * n / denom))


@dataclasses.dataclass
class SketchDisclosure:
    m: int
    n: int
    gamma: float
    tag: str = ""

    @property
    def per_entry_nats(self) -> float:
        return mi_per_entry_bound(self.m, self.n, self.gamma)


@dataclasses.dataclass
class PrivacyAccountant:
    """Aggregates worst-case MI leakage across all sketched shipments of a dataset.

    Independent sketches S_1..S_q of the same A compose additively in the worst case:
    I((S_1A,...,S_qA); A) ≤ Σ_k I(S_kA; A) — equivalently one tall sketch with q·m rows.
    """

    disclosures: List[SketchDisclosure] = dataclasses.field(default_factory=list)

    def record(self, m: int, n: int, gamma: float = 1.0, tag: str = "") -> SketchDisclosure:
        d = SketchDisclosure(m=m, n=n, gamma=gamma, tag=tag)
        self.disclosures.append(d)
        return d

    @property
    def total_per_entry_nats(self) -> float:
        return sum(d.per_entry_nats for d in self.disclosures)

    def report(self) -> str:
        lines = ["privacy accountant (Eq. 5 worst-case MI, nats/entry):"]
        for d in self.disclosures:
            lines.append(f"  [{d.tag or 'sketch'}] m={d.m} n={d.n} γ={d.gamma:g} -> {d.per_entry_nats:.3e}")
        lines.append(f"  TOTAL: {self.total_per_entry_nats:.3e}")
        return "\n".join(lines)
