"""Core library: the paper's contribution (distributed sketching for regression)."""
from repro.core.sketches import SketchSpec, apply_sketch, sketch_data, materialize
from repro.core.operators import (
    SketchOp,
    make_operator,
    apply_batched,
    apply_blocked,
    sketch_data_batched,
)
from repro.core.solve import (
    lstsq,
    least_norm,
    sketch_and_solve,
    sketch_least_norm,
    residual_cost,
    relative_error,
)
from repro.core.averaging import masked_average, psum_average, StreamingAverage
from repro.core import theory, privacy, distributed, ihs, gradcomp, operators
