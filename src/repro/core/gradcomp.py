"""Sketched gradient compression for data-parallel all-reduce (beyond-paper).

The paper's operators (E[SᵀS] = I) give an *unbiased* linear compressor: a DP worker
projects its gradient g → Sg (m ≪ D), the mesh psums in sketch space (m floats instead
of D), and the master unsketches ŷ = Sᵀ(mean_k S g_k). With every worker using the
SAME S per step (derived from the step key — no coordination needed, keys are
deterministic), the psum commutes with the sketch and

    E[Sᵀ S ḡ] = ḡ,

i.e. the compressed all-reduce is an unbiased estimate of the true mean gradient with
variance ~ (D/m)·‖ḡ‖²/m-ish — the classic random-projection trade-off. CountSketch
(SJLT s=1) makes both ends O(D) time. This is exactly Algorithm 1's privacy/bandwidth
mechanism applied to the optimizer's communication instead of the data matrix.

Modes:
  * ``same_sketch``  (default): bandwidth compression, unbiased, variance added.
  * ``fresh_sketch``: each worker uses its own S_k — the psum then averages q
    independent unbiased estimates Sₖᵀ Sₖ g_k, reducing the sketch-induced variance by
    q (Lemma-2 logic applied to gradients) at the cost of no bandwidth saving unless
    combined with a two-stage (compress → psum → decompress per-worker) schedule.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import averaging, operators
from repro.core.sketches import SketchSpec
from repro.utils import tree as tu


@dataclasses.dataclass(frozen=True)
class GradCompressionConfig:
    enabled: bool = False
    ratio: float = 0.1          # m = ceil(ratio * D)
    kind: str = "countsketch"   # countsketch | gaussian
    mode: str = "same_sketch"   # same_sketch | fresh_sketch
    min_size: int = 4096        # leaves smaller than this are sent uncompressed


def _sketch_spec(cfg: GradCompressionConfig, m: int) -> SketchSpec:
    """The compressor as a SketchOp spec: CountSketch is SJLT with s = 1."""
    if cfg.kind == "countsketch":
        return SketchSpec("sjlt", m, s=1)
    if cfg.kind == "gaussian":
        return SketchSpec("gaussian", m)
    raise ValueError(cfg.kind)


def compress(cfg: GradCompressionConfig, key: jax.Array, grads):
    """Project the gradient pytree into sketch space. Returns (payload, ctx).

    The projection/backprojection pair is a ``SketchOp`` and its adjoint
    (E[SᵀS] = I ⇒ unbiased), from the same registry the solvers dispatch through.
    """
    vec, vz = tu.tree_flatten_to_vector(grads)
    D = vec.shape[0]
    m = max(1, int(math.ceil(cfg.ratio * D)))
    op = operators.make_operator(_sketch_spec(cfg, m), key, D)
    return op.apply(vec), (op, vz)


def decompress(cfg: GradCompressionConfig, payload, ctx):
    op, vz = ctx
    return vz.unflatten(op.adjoint(payload))


def compressed_psum_mean(cfg: GradCompressionConfig, key: jax.Array, grads, axis_names):
    """Inside shard_map/pmap: all-reduce-mean the gradient tree in sketch space.

    Every worker derives the same S from ``key`` (same_sketch mode) so the linear
    sketch commutes with psum; fresh_sketch folds in the worker index first.
    """
    if not cfg.enabled:
        summed = jax.tree_util.tree_map(lambda g: jax.lax.pmean(g, axis_names), grads)
        return summed
    if cfg.mode == "fresh_sketch":
        key = jax.random.fold_in(key, averaging.worker_index(axis_names))
        payload, ctx = compress(cfg, key, grads)
        local = decompress(cfg, payload, ctx)
        return jax.tree_util.tree_map(lambda g: jax.lax.pmean(g, axis_names), local)
    payload, ctx = compress(cfg, key, grads)
    payload = jax.lax.pmean(payload, axis_names)
    return decompress(cfg, payload, ctx)


def compression_error(cfg: GradCompressionConfig, key: jax.Array, grads):
    """‖decompress(compress(g)) − g‖ / ‖g‖ — used by tests and benchmarks."""
    payload, ctx = compress(cfg, key, grads)
    rec = decompress(cfg, payload, ctx)
    num = tu.tree_global_norm(jax.tree_util.tree_map(jnp.subtract, rec, grads))
    den = tu.tree_global_norm(grads)
    return num / (den + 1e-30)
