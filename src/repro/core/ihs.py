"""Iterative Hessian Sketch (Pilanci & Wainwright 2016) — the paper's reference [11].

Implemented as the *baseline the paper compares its one-shot averaging against*:
IHS refines x_t with a fresh sketched Hessian each iteration,

    x_{t+1} = x_t + (Aᵀ S_tᵀ S_t A)⁻¹ Aᵀ (b − A x_t),

converging geometrically but requiring synchronous rounds (each iteration needs the
previous iterate — no straggler resilience), whereas Algorithm 1's averaging is fully
asynchronous. Benchmarks put both on the same plots.

The sketches S_t are independent of the iterates, and IHS only ever consumes ``S_t A``
through its Gram ``H_t = (S_tA)ᵀ(S_tA)`` — so all ``iters`` sketched Hessians are
computed up front by ``operators.gram_batched``, the fused single-pass sketch→Gram
path: one read of A total, O(iters·d²) resident instead of O(iters·m·d), SA never
materialized. The refinement loop is a ``lax.scan`` over the precomputed Grams.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import operators, sketches as sk
from repro.utils import prng


def _ihs_scan(spec, key, A, b, iters: int, reg: float):
    d = A.shape[1]
    keys = prng.worker_keys(key, iters)
    # Fused sketch→Gram: all iters Hessians (iters, d, d) in one pass over A each,
    # without ever materializing any (m, d) sketch factor.
    Gs, _ = operators.gram_batched(spec, keys, A)

    def step(x, G):
        H = G.astype(A.dtype) + reg * jnp.eye(d, dtype=A.dtype)
        g = A.T @ (b - A @ x)
        L = jnp.linalg.cholesky(H)
        y = jax.scipy.linalg.solve_triangular(L, g, lower=True)
        x = x + jax.scipy.linalg.solve_triangular(L.T, y, lower=False)
        return x, x

    x0 = jnp.zeros((d,), A.dtype)
    return jax.lax.scan(step, x0, Gs)


def ihs_solve(
    spec: sk.SketchSpec,
    key: jax.Array,
    A: jax.Array,
    b: jax.Array,
    *,
    iters: int = 10,
    reg: float = 0.0,
) -> jax.Array:
    """Run ``iters`` IHS iterations. spec.m should be >= ~2d for geometric decay."""
    x, _ = _ihs_scan(spec, key, A, b, iters, reg)
    return x


def ihs_trace(spec, key, A, b, *, iters: int = 10, reg: float = 0.0):
    """Like ihs_solve but returns the iterate after every step (for benchmarks)."""
    _, trace = _ihs_scan(spec, key, A, b, iters, reg)
    return trace
