"""`SketchOp`: the paper's sketch family as composable linear operators.

Every sketch ``S ∈ R^{m×n}`` in the repo used to exist only as a *function*
``(key, A) -> S @ A`` dispatched through a string-keyed if-chain. This module turns
each kind into a frozen linear-operator object built once from ``(SketchSpec, key, n)``
and exposing the full operator calculus the pipeline needs:

  * ``apply(A)``                 — ``S @ A`` (fast path per kind; Pallas kernel when
                                   ``spec.use_kernel`` and one exists),
  * ``adjoint(Y)``               — ``Sᵀ @ Y`` without ever materializing S (scatter for
                                   sampling sketches, FWHT for SRHT, gather for SJLT,
                                   streamed counter-RNG tiles for Gaussian),
  * ``apply_blocked(A, block_rows=...)`` — a ``lax.scan`` over row tiles of A, so ``n``
                                   can exceed device memory: each sketch is a sum /
                                   gather over row blocks and tile ``(i, j)`` of the
                                   random S is a pure function of ``(key, i, j)``
                                   (counter RNG, shared with ``repro.kernels``),
  * ``materialize()``            — explicit S for tests / tiny problems.

A registry (``@register(kind)`` → ``make_operator``) replaces every if-chain dispatch,
including the ``use_kernel`` routing into the Pallas kernels. Multi-worker callers use

  * :func:`apply_batched` — vmap ``q`` independent sketches over a *single* read of A
    (Algorithm 1's master-sketch mode, IHS's per-iteration sketches, head fitting),
  * :func:`sketch_data_batched` — the batched ``(S_k A, S_k b)`` pairs of Algorithm 1.

Randomness contract
-------------------
All per-element randomness is counter-based (threefry2x32 from ``repro.kernels.common``):
entry/row parameters are pure functions of ``(key, global index)``. This is what makes
``apply_blocked`` produce bit-comparable results for *any* block size, and what lets
the Pallas Gaussian/SJLT kernels draw the *same* S as the pure-jnp paths. Only the
O(m) row-sampling draws (uniform/leverage/SRHT row picks, hybrid's row subset) use
ordinary ``jax.random`` calls — they are tiny and never need streaming.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import sketches as sk
from repro.kernels import common as kcommon
from repro.utils import env as envcfg

# Default row-tile for blocked/streamed application. 4096 rows × 512 cols of f32 is
# 8 MiB — comfortably inside a v5e core's VMEM budget alongside the (m, block) S tile.
DEFAULT_BLOCK_ROWS = 4096


# ----------------------------------------------------------------------- registry

_REGISTRY: Dict[str, type] = {}


def register(kind: str) -> Callable[[type], type]:
    """Class decorator: make ``kind`` constructible through :func:`make_operator`."""

    def deco(cls: type) -> type:
        _REGISTRY[kind] = cls
        return cls

    return deco


def registered_kinds() -> tuple:
    return tuple(sorted(_REGISTRY))


def make_operator(
    spec: sk.SketchSpec,
    key: jax.Array,
    n: int,
    *,
    scores: Optional[jax.Array] = None,
) -> "SketchOp":
    """Build the frozen ``S ∈ R^{m×n}`` described by ``spec`` from ``key``.

    ``scores``: leverage scores (required for ``kind="leverage"``, ignored otherwise);
    data-dependent sketches must be given their data statistics explicitly so the
    resulting object is a *fixed* linear operator.
    """
    try:
        cls = _REGISTRY[spec.kind]
    except KeyError:
        raise ValueError(
            f"no SketchOp registered for kind {spec.kind!r}; known: {registered_kinds()}"
        ) from None
    return cls.build(spec, key, n, scores=scores)


# --------------------------------------------------------------------- shape utils


def _to_2d(X: jax.Array, rows: int):
    """View (rows, ...) as (rows, k); returns the 2-D view and the trailing shape."""
    if X.shape[0] != rows:
        raise ValueError(f"operator expects leading dim {rows}, got shape {X.shape}")
    return X.reshape(rows, -1), X.shape[1:]


def _from_2d(Y2: jax.Array, batch: tuple) -> jax.Array:
    return Y2.reshape((Y2.shape[0],) + batch)


def _scan_row_blocks(
    A2: jax.Array, n: int, block_rows: int, init: jax.Array, reducer, *, double_buffer: bool = True
):
    """Shared blocked-streaming scaffold: ``lax.scan`` of ``reducer(acc, j0, A_blk)``
    over zero-padded f32 row tiles of A2 (2-D). Zero rows beyond n contribute
    nothing to any registered reducer (matmul against zeros / gather of zeros /
    scatter of zeros), so no masking is needed.

    Double-buffered by default: the scan carry holds the *prefetched* next tile
    alongside the accumulator, and each step issues the fetch of tile i+1 before
    consuming tile i. The fetch has no data dependence on the reduction, so XLA is
    free to overlap the copy/DMA of the next tile with the current tile's matmul —
    the classic two-slot pipeline, expressed as an async-friendly scan carry. The
    eager pre-reshaped path is kept (``double_buffer=False``) as the reference.
    """
    bs = max(1, min(block_rows, n))
    nb = -(-n // bs)
    if nb * bs != n:
        A2 = jnp.pad(A2, ((0, nb * bs - n), (0, 0)))
    Af = A2.astype(jnp.float32)

    if nb == 1:
        return reducer(init, jnp.int32(0), Af)

    if not double_buffer:
        blocks = Af.reshape(nb, bs, Af.shape[1])
        j0s = jnp.arange(nb, dtype=jnp.int32) * bs

        def body(acc, xs):
            j0, Ab = xs
            return reducer(acc, j0, Ab), None

        acc, _ = jax.lax.scan(body, init, (j0s, blocks))
        return acc

    def fetch(i):
        return jax.lax.dynamic_slice_in_dim(Af, i * bs, bs, axis=0)

    def body(carry, i):
        acc, cur = carry
        nxt = fetch(jnp.minimum(i + 1, nb - 1))  # prefetch: independent of the reduce
        acc = reducer(acc, i * bs, cur)
        return (acc, nxt), None

    (acc, _), _ = jax.lax.scan(body, (init, fetch(jnp.int32(0))), jnp.arange(nb, dtype=jnp.int32))
    return acc


def _scan_row_blocks_joint(
    A2: jax.Array, B2: jax.Array, n: int, block_rows: int, init: jax.Array, reducer
):
    """Like :func:`_scan_row_blocks`, but streams matching row tiles of two arrays
    and hands the reducer their *tile-level* join ``[A_blk | B_blk]``.

    Joining per tile keeps the copy cache-resident (the joined tile is consumed
    immediately), instead of materializing a full (n, d+k) concatenation in HBM
    and re-reading it — one whole DRAM round trip of A saved per gram pass.
    """
    bs = max(1, min(block_rows, n))
    nb = -(-n // bs)
    if nb * bs != n:
        A2 = jnp.pad(A2, ((0, nb * bs - n), (0, 0)))
        B2 = jnp.pad(B2, ((0, nb * bs - n), (0, 0)))
    Af = A2.astype(jnp.float32)
    Bf = B2.astype(jnp.float32)

    def fetch(i):
        return jnp.concatenate(
            [
                jax.lax.dynamic_slice_in_dim(Af, i * bs, bs, axis=0),
                jax.lax.dynamic_slice_in_dim(Bf, i * bs, bs, axis=0),
            ],
            axis=1,
        )

    if nb == 1:
        return reducer(init, jnp.int32(0), fetch(jnp.int32(0)))

    def body(carry, i):
        acc, cur = carry
        nxt = fetch(jnp.minimum(i + 1, nb - 1))  # prefetch: independent of the reduce
        acc = reducer(acc, i * bs, cur)
        return (acc, nxt), None

    (acc, _), _ = jax.lax.scan(body, (init, fetch(jnp.int32(0))), jnp.arange(nb, dtype=jnp.int32))
    return acc


def _join_b(A: jax.Array, b: Optional[jax.Array]):
    """Stack ``[A | b]`` so one pass sketches both; returns the joined 2-D matrix."""
    if A.ndim != 2:
        raise ValueError(f"gram_blocked expects A of shape (n, d), got {A.shape}")
    if b is None:
        return A
    bm = b if b.ndim == 2 else b[:, None]
    return jnp.concatenate([A, bm.astype(A.dtype)], axis=1)


def _split_gram(Gf: jax.Array, d: int, b: Optional[jax.Array]):
    """Carve (G, c) out of the joint Gram of [A | b]: G = (SA)ᵀ(SA), c = (SA)ᵀ(Sb)."""
    G = Gf[:d, :d]
    if b is None:
        return G, None
    c = Gf[:d, d:]
    return G, (c[:, 0] if b.ndim == 1 else c)


def _split_gram_batched(Gf: jax.Array, d: int, b: Optional[jax.Array]):
    """Batched :func:`_split_gram`: carve (q, d, d) G's and (q, d[, k]) c's out of
    the (q, d+k, d+k) joint Grams of [A | b]."""
    G = Gf[:, :d, :d]
    if b is None:
        return G, None
    c = Gf[:, :d, d:]
    return G, (c[..., 0] if b.ndim == 1 else c)


def _gather_rows_reducer(rows: jax.Array):
    """Reducer accumulating ``A[rows]`` from row blocks: O(len(rows)·k) per block
    (a mask-and-gather), not a dense one-hot matmul."""

    def reducer(acc, j0, Ab):
        local = rows - j0
        in_blk = (local >= 0) & (local < Ab.shape[0])
        idx = jnp.clip(local, 0, Ab.shape[0] - 1)
        return acc + jnp.where(in_blk[:, None], jnp.take(Ab, idx, axis=0), 0.0)

    return reducer


# -------------------------------------------------------------------------- base


@dataclasses.dataclass(frozen=True)
class SketchOp:
    """Frozen linear operator S ∈ R^{m×n} (base class).

    Subclasses either implement :meth:`columns` — an arbitrary column block of S,
    valid for traced start offsets — and inherit generic blocked apply/adjoint, or
    override the generic methods with cheaper structure-aware code (SJLT, hybrid).
    """

    spec: sk.SketchSpec
    key: jax.Array
    n: int

    @property
    def m(self) -> int:
        return self.spec.m

    @property
    def shape(self) -> tuple:
        return (self.m, self.n)

    # -- construction ------------------------------------------------------------

    @classmethod
    def build(cls, spec, key, n, *, scores=None) -> "SketchOp":
        raise NotImplementedError

    @classmethod
    def gram_batched_kernel(cls, spec, keys, A, b):
        """All ``q`` workers' joint Grams ``(G_k, c_k)`` from ONE fused kernel
        launch over ONE read of A — the multi-worker form of the kernel-routed
        :meth:`gram_blocked`. Returns ``NotImplemented`` when the kind has no
        multi-worker kernel; :func:`gram_batched` then falls back to per-key
        dispatch. Worker slice ``w`` must be bitwise-identical to the per-key
        kernel path under ``keys[w]``.
        """
        return NotImplemented

    # -- required tile primitive --------------------------------------------------

    def columns(self, j0, block: int) -> jax.Array:
        """``S[:, j0 : j0+block]`` as an (m, block) tile. ``j0`` may be traced.

        Column indices ≥ n are permitted (blocked application pads A's rows with
        zeros, so out-of-range columns multiply zeros and contribute nothing); the
        values there only need to be finite.
        """
        raise NotImplementedError(f"{type(self).__name__} does not expose S tiles")

    # -- operator calculus --------------------------------------------------------

    def apply(self, A: jax.Array) -> jax.Array:
        """``S @ A`` for A of shape (n, ...). Default: one full-width tile."""
        A2, batch = _to_2d(A, self.n)
        out = (self.columns(0, self.n) @ A2.astype(jnp.float32)).astype(A.dtype)
        return _from_2d(out, batch)

    def _stream_pieces(self, k: int):
        """The kind's blocked-streaming triple ``(init, reducer, finish)`` for a
        width-k right-hand side: ``acc := init``; ``acc = reducer(acc, j0, tile)``
        over row tiles; ``S @ X = finish(acc)``. One primitive powers both
        :meth:`apply_blocked` and the fused :meth:`gram_blocked`.

        Default: dense S tiles from :meth:`columns` (Gaussian, SRHT closed form).
        """
        init = jnp.zeros((self.m, k), jnp.float32)
        reducer = lambda acc, j0, Ab: acc + self.columns(j0, Ab.shape[0]) @ Ab
        return init, reducer, lambda acc: acc

    def apply_blocked(
        self, A: jax.Array, *, block_rows: int = DEFAULT_BLOCK_ROWS
    ) -> jax.Array:
        """``S @ A`` streamed as a ``lax.scan`` over row tiles of A.

        Peak live memory is O(block_rows · k + m · k) instead of O(n · k): the
        sketch never needs all of A resident. Matches :meth:`apply` to float
        tolerance for any ``block_rows`` (including ones that don't divide n).
        """
        A2, batch = _to_2d(A, self.n)
        init, reducer, finish = self._stream_pieces(A2.shape[1])
        acc = _scan_row_blocks(A2, self.n, block_rows, init, reducer)
        return _from_2d(finish(acc).astype(A.dtype), batch)

    def adjoint(self, Y: jax.Array, *, block_rows: int = DEFAULT_BLOCK_ROWS) -> jax.Array:
        """``Sᵀ @ Y`` for Y of shape (m, ...), streamed over column tiles of S."""
        Y2, batch = _to_2d(Y, self.m)
        Yf = Y2.astype(jnp.float32)
        bs = max(1, min(block_rows, self.n))
        nb = -(-self.n // bs)
        j0s = jnp.arange(nb, dtype=jnp.int32) * bs

        def body(_, j0):
            return None, self.columns(j0, bs).T @ Yf  # (bs, k)

        _, outs = jax.lax.scan(body, None, j0s)
        out = outs.reshape(nb * bs, Yf.shape[1])[: self.n]
        return _from_2d(out.astype(Y.dtype), batch)

    def gram_blocked(
        self,
        A: jax.Array,
        b: Optional[jax.Array] = None,
        *,
        block_rows: int = DEFAULT_BLOCK_ROWS,
    ):
        """Fused single-pass sketch→Gram: ``(G, c)`` with ``G = (SA)ᵀ(SA)`` (d, d)
        and ``c = (SA)ᵀ(Sb)`` (``None`` when b is), from ONE streamed pass over
        ``[A | b]``.

        This is everything the sketched normal equations need — the m×d problem is
        then a Cholesky on G. The (m, d+k) sketch accumulator rides in the scan
        carry (double-buffered row tiles, with ``[A_blk | b_blk]`` joined at tile
        granularity so no full concatenation ever hits HBM); SA is never written
        back for large n, and the Gram is a single tiny trailing contraction.
        Kernel-routed kinds override this with fully fused Pallas kernels that
        also keep S in-core.
        """
        if A.ndim != 2:
            raise ValueError(f"gram_blocked expects A of shape (n, d), got {A.shape}")
        bm = None if b is None else (b if b.ndim == 2 else b[:, None])
        k = A.shape[1] + (0 if bm is None else bm.shape[1])
        init, reducer, finish = self._stream_pieces(k)
        if bm is None:
            acc = _scan_row_blocks(A, self.n, block_rows, init, reducer)
        else:
            acc = _scan_row_blocks_joint(A, bm, self.n, block_rows, init, reducer)
        SAb = finish(acc).astype(jnp.float32)
        return _split_gram(SAb.T @ SAb, A.shape[1], b)

    def materialize(self, dtype=jnp.float32) -> jax.Array:
        """Explicit S ∈ R^{m×n} (tests / small problems only)."""
        return self.apply(jnp.eye(self.n, dtype=dtype))


# ----------------------------------------------------------------------- gaussian


@register("gaussian")
@dataclasses.dataclass(frozen=True)
class GaussianOp(SketchOp):
    """i.i.d. N(0, 1/m) entries from the counter stream: S[i, j] = f(key, i, j).

    The exact same stream the RNG-fused Pallas kernel generates tile-by-tile
    (``repro.kernels.gaussian``), so the kernel path, the jnp path, blocked
    streaming, and the adjoint all agree on S.
    """

    k0: jax.Array = None
    k1: jax.Array = None

    @classmethod
    def build(cls, spec, key, n, *, scores=None):
        k0, k1 = kcommon.key_to_words(key)
        return cls(spec=spec, key=key, n=n, k0=k0, k1=k1)

    def columns(self, j0, block: int) -> jax.Array:
        rows = jax.lax.broadcasted_iota(jnp.uint32, (self.m, block), 0)
        cols = jnp.uint32(j0) + jax.lax.broadcasted_iota(jnp.uint32, (self.m, block), 1)
        z = kcommon.counter_normal(self.k0, self.k1, rows, cols)
        return z * jnp.float32(1.0 / math.sqrt(self.m))

    def apply(self, A: jax.Array) -> jax.Array:
        if self.spec.use_kernel:
            from repro.kernels.gaussian import ops as gops

            A2, batch = _to_2d(A, self.n)
            return _from_2d(gops.gaussian_sketch(self.key, A2, self.m), batch)
        return super().apply(A)

    def adjoint(self, Y: jax.Array, *, block_rows: int = DEFAULT_BLOCK_ROWS) -> jax.Array:
        if self.spec.use_kernel:
            from repro.kernels.gaussian import ops as gops

            Y2, batch = _to_2d(Y, self.m)
            out = gops.gaussian_adjoint(self.key, Y2, self.n)
            return _from_2d(out.astype(Y.dtype), batch)
        return super().adjoint(Y, block_rows=block_rows)

    def gram_blocked(
        self,
        A: jax.Array,
        b: Optional[jax.Array] = None,
        *,
        block_rows: int = DEFAULT_BLOCK_ROWS,
    ):
        if self.spec.use_kernel:
            from repro.kernels.gaussian import ops as gops

            Gf = gops.gaussian_gram(self.key, _join_b(A, b), self.m)
            return _split_gram(Gf, A.shape[1], b)
        return super().gram_blocked(A, b, block_rows=block_rows)

    @classmethod
    def gram_batched_kernel(cls, spec, keys, A, b):
        from repro.kernels.gaussian import ops as gops

        Gf = gops.gaussian_gram_multi(keys, _join_b(A, b), spec.m)
        return _split_gram_batched(Gf, A.shape[1], b)


# --------------------------------------------------------------------- rademacher


@register("rademacher")
@dataclasses.dataclass(frozen=True)
class RademacherOp(SketchOp):
    """i.i.d. ±1/√m entries from the *packed* counter stream: sign(i, j) is bit
    ``j % 32`` of ``threefry(key, i, j // 32)`` — one threefry call per 32 entries
    (``kernels.common.packed_sign_words``), versus one call plus Box-Muller per
    entry for the Gaussian family. Sub-gaussian, so Thm-1-style averaging and the
    embedding bounds carry over (arXiv:2412.20301, arXiv:2203.09755); use it when
    the Gaussian path is RNG-bound. Kernel and jnp paths share the same S.
    """

    k0: jax.Array = None
    k1: jax.Array = None

    @classmethod
    def build(cls, spec, key, n, *, scores=None):
        k0, k1 = kcommon.key_to_words(key)
        return cls(spec=spec, key=key, n=n, k0=k0, k1=k1)

    def columns(self, j0, block: int) -> jax.Array:
        signs = kcommon.counter_rademacher_block(self.k0, self.k1, 0, j0, self.m, block)
        return signs * jnp.float32(1.0 / math.sqrt(self.m))

    def apply(self, A: jax.Array) -> jax.Array:
        if self.spec.use_kernel:
            from repro.kernels.rademacher import ops as rops

            A2, batch = _to_2d(A, self.n)
            return _from_2d(rops.rademacher_sketch(self.key, A2, self.m), batch)
        return super().apply(A)

    def gram_blocked(
        self,
        A: jax.Array,
        b: Optional[jax.Array] = None,
        *,
        block_rows: int = DEFAULT_BLOCK_ROWS,
    ):
        if self.spec.use_kernel:
            from repro.kernels.rademacher import ops as rops

            Gf = rops.rademacher_gram(self.key, _join_b(A, b), self.m)
            return _split_gram(Gf, A.shape[1], b)
        return super().gram_blocked(A, b, block_rows=block_rows)

    @classmethod
    def gram_batched_kernel(cls, spec, keys, A, b):
        from repro.kernels.rademacher import ops as rops

        Gf = rops.rademacher_gram_multi(keys, _join_b(A, b), spec.m)
        return _split_gram_batched(Gf, A.shape[1], b)


# -------------------------------------------------------------------------- srht


@register("srht")
@dataclasses.dataclass(frozen=True)
class SRHTOp(SketchOp):
    """Randomized Hadamard (ROS): S = (1/√m) · P · H · D on the 2^⌈log n⌉ padding.

    ``apply`` uses the O(n log n) FWHT (Pallas kernel when requested); ``columns``
    builds Hadamard tiles H[r, j] = (−1)^popcount(r & j) on the fly, which is what
    makes blocked/streamed application possible without the full transform.
    """

    kd0: jax.Array = None  # sign-counter key words (D diagonal)
    kd1: jax.Array = None
    rows: jax.Array = None  # (m,) sampled Hadamard rows, with replacement
    n_pad: int = 0

    @classmethod
    def build(cls, spec, key, n, *, scores=None):
        n_pad = sk.next_pow2(n)
        kd, kp = jax.random.split(key)
        kd0, kd1 = kcommon.key_to_words(kd)
        rows = jax.random.randint(kp, (spec.m,), 0, n_pad)
        return cls(spec=spec, key=key, n=n, kd0=kd0, kd1=kd1, rows=rows, n_pad=n_pad)

    def _signs(self, j: jax.Array) -> jax.Array:
        """Rademacher diagonal D at (possibly traced) coordinate(s) j."""
        return kcommon.counter_rademacher(self.kd0, self.kd1, j.astype(jnp.uint32), jnp.uint32(0))

    def apply(self, A: jax.Array) -> jax.Array:
        A2, batch = _to_2d(A, self.n)
        DA = A2.astype(jnp.float32) * self._signs(jnp.arange(self.n))[:, None]
        if self.n_pad != self.n:
            DA = jnp.pad(DA, ((0, self.n_pad - self.n), (0, 0)))
        if self.spec.use_kernel:
            from repro.kernels.fwht import ops as fops

            HDA = fops.fwht(DA)
        else:
            HDA = sk._fwht(DA)
        out = jnp.take(HDA, self.rows, axis=0) * jnp.float32(1.0 / math.sqrt(self.m))
        return _from_2d(out.astype(A.dtype), batch)

    def columns(self, j0, block: int) -> jax.Array:
        j = jnp.uint32(j0) + jnp.arange(block, dtype=jnp.uint32)
        # Sylvester closed form: H[r, j] = (−1)^popcount(r & j) — no transform needed.
        parity = jax.lax.population_count(self.rows.astype(jnp.uint32)[:, None] & j[None, :])
        h = (1 - 2 * (parity & jnp.uint32(1)).astype(jnp.int32)).astype(jnp.float32)
        return h * self._signs(j)[None, :] * jnp.float32(1.0 / math.sqrt(self.m))

    def adjoint(self, Y: jax.Array, *, block_rows: int = DEFAULT_BLOCK_ROWS) -> jax.Array:
        Y2, batch = _to_2d(Y, self.m)
        # Sᵀ = (1/√m) · D · Hᵀ · Pᵀ with H symmetric; Pᵀ is scatter-add (P repeats rows).
        Z = jnp.zeros((self.n_pad, Y2.shape[1]), jnp.float32).at[self.rows].add(
            Y2.astype(jnp.float32)
        )
        HZ = sk._fwht(Z)[: self.n]
        out = HZ * self._signs(jnp.arange(self.n))[:, None] * jnp.float32(1.0 / math.sqrt(self.m))
        return _from_2d(out.astype(Y.dtype), batch)

    def gram_blocked(
        self,
        A: jax.Array,
        b: Optional[jax.Array] = None,
        *,
        block_rows: int = DEFAULT_BLOCK_ROWS,
    ):
        if self.spec.use_kernel:
            from repro.kernels.fwht import ops as fops

            key_words = jnp.stack([self.kd0, self.kd1])
            Gf = fops.srht_gram(_join_b(A, b), self.rows, key_words)
            return _split_gram(Gf, A.shape[1], b)
        # Non-kernel: the transform is global, so streamed Sylvester tiles would
        # trade the O(n log n · k) FWHT for an O(n·m·k) matmul — a big loss. One
        # FWHT apply then the tiny (m, d+k) Gram is the fast single pass here;
        # only the Pallas closed-form kernel makes true tile streaming pay.
        SAb = self.apply(_join_b(A, b)).astype(jnp.float32)
        return _split_gram(SAb.T @ SAb, A.shape[1], b)

    @classmethod
    def gram_batched_kernel(cls, spec, keys, A, b):
        from repro.kernels.fwht import ops as fops

        n_pad = sk.next_pow2(A.shape[0])

        def params(key):
            # Mirrors build() exactly — vmapped jax.random draws are elementwise
            # deterministic per key, so rows/words bitwise-match the per-op build.
            kd, kp = jax.random.split(key)
            kd0, kd1 = kcommon.key_to_words(kd)
            rows = jax.random.randint(kp, (spec.m,), 0, n_pad)
            return rows, jnp.stack([kd0, kd1])

        rows, key_words = jax.vmap(params)(keys)
        Gf = fops.srht_gram_multi(_join_b(A, b), rows, key_words)
        return _split_gram_batched(Gf, A.shape[1], b)


# ------------------------------------------------------------------ row sampling


@register("uniform")
@dataclasses.dataclass(frozen=True)
class UniformOp(SketchOp):
    """Uniform row sampling scaled by √(n/m) so E[SᵀS] = I."""

    rows: jax.Array = None  # (m,)

    @classmethod
    def build(cls, spec, key, n, *, scores=None):
        if spec.replacement:
            rows = jax.random.randint(key, (spec.m,), 0, n)
        else:
            # Gumbel top-k == sampling without replacement, jit-friendly.
            g = jax.random.gumbel(key, (n,))
            rows = jax.lax.top_k(g, spec.m)[1]
        return cls(spec=spec, key=key, n=n, rows=rows)

    @property
    def _scale(self) -> float:
        return math.sqrt(self.n / self.m)

    def apply(self, A: jax.Array) -> jax.Array:
        return jnp.take(A, self.rows, axis=0) * jnp.asarray(self._scale, A.dtype)

    def columns(self, j0, block: int) -> jax.Array:
        j = jnp.int32(j0) + jnp.arange(block, dtype=jnp.int32)
        onehot = (self.rows[:, None] == j[None, :]).astype(jnp.float32)
        return onehot * jnp.float32(self._scale)

    def _stream_pieces(self, k: int):
        init = jnp.zeros((self.m, k), jnp.float32)
        return init, _gather_rows_reducer(self.rows), lambda acc: acc * jnp.float32(self._scale)

    def adjoint(self, Y: jax.Array, *, block_rows: int = DEFAULT_BLOCK_ROWS) -> jax.Array:
        Y2, batch = _to_2d(Y, self.m)
        out = jnp.zeros((self.n, Y2.shape[1]), Y2.dtype).at[self.rows].add(Y2)
        return _from_2d(out * jnp.asarray(self._scale, Y.dtype), batch)


@register("leverage")
@dataclasses.dataclass(frozen=True)
class LeverageOp(SketchOp):
    """Leverage-score sampling: P[row j] ∝ ℓ_j, kept row scaled by 1/√(m·p_j)."""

    rows: jax.Array = None  # (m,)
    scales: jax.Array = None  # (m,)

    @classmethod
    def build(cls, spec, key, n, *, scores=None):
        if scores is None:
            raise ValueError(
                "leverage sketches are data-dependent: pass scores= to make_operator "
                "(e.g. sketches.leverage_scores(A)) so the operator is fixed"
            )
        p = scores / jnp.sum(scores)
        rows = jax.random.categorical(key, jnp.log(p + 1e-30), shape=(spec.m,))
        scales = 1.0 / jnp.sqrt(spec.m * jnp.take(p, rows))
        return cls(spec=spec, key=key, n=n, rows=rows, scales=scales)

    def apply(self, A: jax.Array) -> jax.Array:
        scl = self.scales.astype(A.dtype)
        return jnp.take(A, self.rows, axis=0) * scl.reshape((self.m,) + (1,) * (A.ndim - 1))

    def columns(self, j0, block: int) -> jax.Array:
        j = jnp.int32(j0) + jnp.arange(block, dtype=jnp.int32)
        onehot = (self.rows[:, None] == j[None, :]).astype(jnp.float32)
        return onehot * self.scales.astype(jnp.float32)[:, None]

    def _stream_pieces(self, k: int):
        init = jnp.zeros((self.m, k), jnp.float32)
        finish = lambda acc: acc * self.scales.astype(jnp.float32)[:, None]
        return init, _gather_rows_reducer(self.rows), finish

    def adjoint(self, Y: jax.Array, *, block_rows: int = DEFAULT_BLOCK_ROWS) -> jax.Array:
        Y2, batch = _to_2d(Y, self.m)
        contrib = Y2 * self.scales.astype(Y2.dtype)[:, None]
        out = jnp.zeros((self.n, Y2.shape[1]), Y2.dtype).at[self.rows].add(contrib)
        return _from_2d(out, batch)


# -------------------------------------------------------------------------- sjlt


@register("sjlt")
@dataclasses.dataclass(frozen=True)
class SJLTOp(SketchOp):
    """Sparse JL: s nonzeros (±1/√s) per input coordinate, counter-derived per row.

    Row parameters come from :func:`repro.kernels.common.sjlt_counter_params`, the
    same draw the Pallas kernel consumes — kernel and jnp paths share S exactly.
    """

    k0: jax.Array = None
    k1: jax.Array = None

    @classmethod
    def build(cls, spec, key, n, *, scores=None):
        k0, k1 = kcommon.key_to_words(key)
        return cls(spec=spec, key=key, n=n, k0=k0, k1=k1)

    def _params(self, row_idx: jax.Array):
        return kcommon.sjlt_counter_params(self.k0, self.k1, row_idx, self.spec.s, self.m)

    def _segment_apply(self, A2: jax.Array, row_idx: jax.Array) -> jax.Array:
        buckets, signs = self._params(row_idx)
        r, s = buckets.shape
        vals = (signs[..., None] * A2[:, None, :]).reshape(r * s, A2.shape[1])
        return jax.ops.segment_sum(vals, buckets.reshape(-1), num_segments=self.m)

    def apply(self, A: jax.Array) -> jax.Array:
        A2, batch = _to_2d(A, self.n)
        if self.spec.use_kernel:
            from repro.kernels.sjlt import ops as sops

            buckets, signs = self._params(jnp.arange(self.n))
            out = sops.sjlt_apply(A2, buckets, signs, self.m)
        else:
            out = self._segment_apply(A2.astype(jnp.float32), jnp.arange(self.n)).astype(A.dtype)
        return _from_2d(out, batch)

    def _stream_pieces(self, k: int):
        init = jnp.zeros((self.m, k), jnp.float32)
        reducer = lambda acc, j0, Ab: acc + self._segment_apply(
            Ab, j0 + jnp.arange(Ab.shape[0], dtype=jnp.int32)
        )
        return init, reducer, lambda acc: acc

    def adjoint(self, Y: jax.Array, *, block_rows: int = DEFAULT_BLOCK_ROWS) -> jax.Array:
        Y2, batch = _to_2d(Y, self.m)
        buckets, signs = self._params(jnp.arange(self.n))  # (n, s)
        gathered = jnp.take(Y2.astype(jnp.float32), buckets, axis=0)  # (n, s, k)
        out = jnp.sum(gathered * signs[..., None], axis=1)
        return _from_2d(out.astype(Y.dtype), batch)

    def gram_blocked(
        self,
        A: jax.Array,
        b: Optional[jax.Array] = None,
        *,
        block_rows: int = DEFAULT_BLOCK_ROWS,
    ):
        if self.spec.use_kernel:
            from repro.kernels.sjlt import ops as sops

            buckets, signs = self._params(jnp.arange(self.n))
            Gf = sops.sjlt_gram(_join_b(A, b), buckets, signs, self.m)
            return _split_gram(Gf, A.shape[1], b)
        return super().gram_blocked(A, b, block_rows=block_rows)

    @classmethod
    def gram_batched_kernel(cls, spec, keys, A, b):
        from repro.kernels.sjlt import ops as sops

        row_idx = jnp.arange(A.shape[0])
        words = kcommon.keys_to_words(keys)  # (q, 2) — same words build() derives
        buckets, signs = jax.vmap(
            lambda w: kcommon.sjlt_counter_params(w[0], w[1], row_idx, spec.s, spec.m)
        )(words)
        Gf = sops.sjlt_gram_multi(_join_b(A, b), buckets, signs, spec.m)
        return _split_gram_batched(Gf, A.shape[1], b)


# ------------------------------------------------------------------------ hybrid


@register("hybrid")
@dataclasses.dataclass(frozen=True)
class HybridOp(SketchOp):
    """Paper §IV-D: uniform-sample m′ rows without replacement (what a worker can
    afford to *read*), then an inner sketch m′ → m (what it can afford to *compute*).

    S = S_inner · U with U the scaled row-subset selector; the operator calculus
    composes: apply = inner∘gather, adjoint = scatter∘innerᵀ."""

    rows: jax.Array = None  # (m_prime,)
    inner: SketchOp = None

    @classmethod
    def build(cls, spec, key, n, *, scores=None):
        k1, k2 = jax.random.split(key)
        g = jax.random.gumbel(k1, (n,))
        rows = jax.lax.top_k(g, spec.m_prime)[1]
        inner_spec = sk.SketchSpec(spec.inner, spec.m, s=spec.s, use_kernel=spec.use_kernel)
        inner = make_operator(inner_spec, k2, spec.m_prime)
        return cls(spec=spec, key=key, n=n, rows=rows, inner=inner)

    @property
    def _scale(self) -> float:
        return math.sqrt(self.n / self.spec.m_prime)

    def apply(self, A: jax.Array) -> jax.Array:
        sampled = jnp.take(A, self.rows, axis=0) * jnp.asarray(self._scale, A.dtype)
        return self.inner.apply(sampled)

    def _stream_pieces(self, k: int):
        # The m′×k intermediate is exactly the "what a worker reads" budget — it is
        # the one thing hybrid sketching keeps resident while streaming over n.
        init = jnp.zeros((self.spec.m_prime, k), jnp.float32)
        finish = lambda acc: self.inner.apply(acc * jnp.float32(self._scale))
        return init, _gather_rows_reducer(self.rows), finish

    def adjoint(self, Y: jax.Array, *, block_rows: int = DEFAULT_BLOCK_ROWS) -> jax.Array:
        Y2, batch = _to_2d(Y, self.m)
        z = self.inner.adjoint(Y2)  # (m_prime, k)
        out = jnp.zeros((self.n, z.shape[1]), z.dtype).at[self.rows].add(z)
        return _from_2d(out * jnp.asarray(self._scale, Y.dtype), batch)


# --------------------------------------------------------- functional entry points


def _scores_for(spec: sk.SketchSpec, A: jax.Array, scores) -> Optional[jax.Array]:
    if spec.kind == "leverage" and scores is None:
        return sk.leverage_scores(A.reshape(A.shape[0], -1))
    return scores


def apply(spec: sk.SketchSpec, key: jax.Array, A: jax.Array, *, scores=None) -> jax.Array:
    """``S @ A`` — the registry-dispatched replacement for the old if-chain."""
    scores = _scores_for(spec, A, scores)
    return make_operator(spec, key, A.shape[0], scores=scores).apply(A)


def apply_blocked(
    spec: sk.SketchSpec,
    key: jax.Array,
    A: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    scores=None,
) -> jax.Array:
    """``S @ A`` streamed over row tiles (out-of-core n)."""
    scores = _scores_for(spec, A, scores)
    return make_operator(spec, key, A.shape[0], scores=scores).apply_blocked(
        A, block_rows=block_rows
    )


def gram_blocked(
    spec: sk.SketchSpec,
    key: jax.Array,
    A: jax.Array,
    b: Optional[jax.Array] = None,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    scores=None,
):
    """Fused single-pass ``(G, c) = ((SA)ᵀ(SA), (SA)ᵀ(Sb))`` — registry-dispatched."""
    scores = _scores_for(spec, A, scores)
    return make_operator(spec, key, A.shape[0], scores=scores).gram_blocked(
        A, b, block_rows=block_rows
    )


def gram_blocked_host(
    spec: sk.SketchSpec,
    key: jax.Array,
    A,
    b=None,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    scores=None,
):
    """Out-of-core :func:`gram_blocked` for A living on the HOST (numpy array or
    ``np.memmap``): n can exceed *device* memory, not just VMEM.

    Streams row tiles through the same ``(init, reducer, finish)`` triple as the
    on-device path, but the scan loop runs in Python with double-buffered async
    ``jax.device_put``: the H2D transfer of tile i+1 is issued *before* the jitted
    reduce step of tile i is dispatched, so (dispatch being async) the copy
    overlaps the compute — the two-slot pipeline of ``_scan_row_blocks``, with the
    host→device link in place of the HBM fetch. Tiles are joined ``[A_blk|b_blk]``
    and zero-padded to a constant shape host-side (one jit compile; zero rows
    contribute nothing to any registered reducer). Device-resident peak memory is
    O(block_rows·k + m·k). The counter-RNG contract makes the result match
    ``gram_blocked`` on device-resident A to float tolerance for any block size.
    """
    import numpy as np

    if A.ndim != 2:
        raise ValueError(f"gram_blocked_host expects A of shape (n, d), got {A.shape}")
    n, d = A.shape
    bm = None if b is None else (b if b.ndim == 2 else np.asarray(b)[:, None])
    k = d + (0 if bm is None else bm.shape[1])
    op = make_operator(spec, key, n, scores=scores)
    init, reducer, finish = op._stream_pieces(k)

    bs = max(1, min(block_rows, n))
    nb = -(-n // bs)

    @jax.jit
    def step(acc, j0, tile):
        return reducer(acc, j0, tile)

    def host_tile(i: int) -> np.ndarray:
        j0 = i * bs
        blk = np.asarray(A[j0 : j0 + bs], dtype=np.float32)
        if bm is not None:
            blk = np.concatenate([blk, np.asarray(bm[j0 : j0 + bs], dtype=np.float32)], axis=1)
        if blk.shape[0] < bs:
            blk = np.concatenate([blk, np.zeros((bs - blk.shape[0], k), np.float32)], axis=0)
        return blk

    acc = init
    nxt = jax.device_put(host_tile(0))
    for i in range(nb):
        cur = nxt
        if i + 1 < nb:
            nxt = jax.device_put(host_tile(i + 1))  # in flight while step(i) runs
        acc = step(acc, jnp.int32(i * bs), cur)
    SAb = finish(acc).astype(jnp.float32)
    return _split_gram(SAb.T @ SAb, d, b)


# ------------------------------------------------------- multi-worker batching


def _mesh_world(mesh, axis_names) -> int:
    q = 1
    for name in axis_names:
        q *= mesh.shape[name]
    return q


def _mesh_batch_enabled() -> bool:
    """Whether batched dispatch may shard worker keys over a provided mesh.

    On real accelerator meshes each worker's sketch runs on its own chip — a q×
    compute win. Forced host "devices" (``--xla_force_host_platform_device_count``)
    share one CPU, so sharding there only adds SPMD partitioning overhead on top of
    the same serial FLOPs; the loop fallback is strictly faster. Override with
    ``REPRO_MESH_BATCH=1`` / ``0`` (tests force the mesh path on fake devices to
    check it is bitwise-identical to the loop).
    """
    forced = envcfg.read_bool("REPRO_MESH_BATCH")
    if forced is not None:
        return forced
    return jax.default_backend() != "cpu"


def _batched_prefers_loop(spec: sk.SketchSpec) -> bool:
    """Backend-aware choice between vmap and a sequential map for worker batching.

    Pallas calls batch unreliably in interpret mode, and the FWHT butterfly vmaps
    poorly off-accelerator — ``results/bench/BENCH_sketch_ops.json`` shows the
    batched SRHT losing to a plain loop on CPU — so both take the sequential map
    (which still reuses the single resident copy of A). Everything else vmaps the
    q projections onto one batched matmul.
    """
    if spec.use_kernel:
        return True
    kinds = {spec.kind} | ({spec.inner} if spec.kind == "hybrid" else set())
    return "srht" in kinds and jax.default_backend() == "cpu"


def _batched_over_keys(per_key, keys: jax.Array, spec: sk.SketchSpec, mesh, axis_names, extras):
    """Run ``per_key(key, *extras)`` for every worker key.

    Dispatch order: ``shard_map`` over the mesh's worker axes when a mesh is given
    and the backend has real devices to shard over (:func:`_mesh_batch_enabled`;
    each shard runs its q/world keys sequentially — bitwise identical to the loop
    fallback under the same keys), else the per-backend loop/vmap choice of
    :func:`_batched_prefers_loop`.
    """
    if mesh is not None and _mesh_batch_enabled():
        world = _mesh_world(mesh, axis_names)
        if world > 1 and keys.shape[0] % world == 0:
            from jax.sharding import PartitionSpec as P

            from repro.utils.compat import shard_map

            def worker(keys_blk, *ex):
                return jax.lax.map(lambda k: per_key(k, *ex), keys_blk)

            fn = shard_map(
                worker,
                mesh=mesh,
                in_specs=(P(axis_names),) + tuple(P() for _ in extras),
                out_specs=P(axis_names),
            )
            return fn(keys, *extras)
    if _batched_prefers_loop(spec):
        return jax.lax.map(lambda k: per_key(k, *extras), keys)
    return jax.vmap(lambda k: per_key(k, *extras))(keys)


def apply_batched(
    spec: sk.SketchSpec,
    keys: jax.Array,
    A: jax.Array,
    *,
    scores=None,
    mesh=None,
    axis_names: tuple = ("workers",),
) -> jax.Array:
    """All ``q`` workers' sketches ``(S_k A)_k`` in one pass over A.

    ``keys``: (q,)-batched PRNG keys (e.g. ``prng.worker_keys``). The q projections
    are either vmapped onto one batched matmul, run as a sequential map (auto-chosen
    per backend — see :func:`_batched_prefers_loop`), or — when ``mesh`` is given
    and q divides the worker-axis world size — sharded across the mesh with one
    replicated read of A per device. Data-dependent statistics (leverage scores)
    are computed once and shared — each worker still draws its own rows.
    Returns a (q, m, ...) stack.
    """
    scores = _scores_for(spec, A, scores)
    n = A.shape[0]
    extras = (A,) + ((scores,) if scores is not None else ())

    def per_key(k, A_, *rest):
        return make_operator(spec, k, n, scores=rest[0] if rest else None).apply(A_)

    return _batched_over_keys(per_key, keys, spec, mesh, axis_names, extras)


def gram_batched(
    spec: sk.SketchSpec,
    keys: jax.Array,
    A: jax.Array,
    b: Optional[jax.Array] = None,
    *,
    scores=None,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    mesh=None,
    axis_names: tuple = ("workers",),
):
    """All ``q`` workers' fused Grams ``(G_k, c_k)`` — the batched form of
    :meth:`SketchOp.gram_blocked`.

    Per worker this moves O(d²) instead of O(m·d) out of the sketch pass (and for
    the fused kernels, nothing of S or SA ever reaches HBM), which is what the
    master-sketch privacy mode ships and what IHS/head-fitting consume. Returns
    ``(Gs, cs)`` of shapes (q, d, d) and (q, d[, k]); ``cs`` is None when b is.

    Kernel-routed kinds with a multi-worker kernel (gaussian/rademacher/sjlt/srht)
    take :meth:`SketchOp.gram_batched_kernel` when no mesh is sharding the keys:
    ONE launch / ONE read of A for all q sketches instead of q kernel launches,
    bitwise-identical per worker to the per-key loop.
    """
    scores = _scores_for(spec, A, scores)
    if spec.use_kernel and (mesh is None or not _mesh_batch_enabled()):
        fused = _REGISTRY[spec.kind].gram_batched_kernel(spec, keys, A, b)
        if fused is not NotImplemented:
            return fused
    n = A.shape[0]
    extras = (A,) + (() if b is None else (b,)) + ((scores,) if scores is not None else ())

    def per_key(k, A_, *rest):
        rest = list(rest)
        b_ = rest.pop(0) if b is not None else None
        sc = rest.pop(0) if scores is not None else None
        op = make_operator(spec, k, n, scores=sc)
        G, c = op.gram_blocked(A_, b_, block_rows=block_rows)
        return (G, c) if b is not None else G

    out = _batched_over_keys(per_key, keys, spec, mesh, axis_names, extras)
    return out if b is not None else (out, None)


def sketch_data_batched(
    spec: sk.SketchSpec,
    keys: jax.Array,
    A: jax.Array,
    b: jax.Array,
    *,
    mesh=None,
    axis_names: tuple = ("workers",),
) -> tuple:
    """Batched Algorithm-1 master step: ``(S_k A, S_k b)`` for every worker key,
    sketching ``[A | b]`` jointly so each worker's pair shares its S."""
    bm = b if b.ndim == 2 else b[:, None]
    d = A.shape[1]
    SAb = apply_batched(
        spec, keys, jnp.concatenate([A, bm], axis=1), mesh=mesh, axis_names=axis_names
    )
    Sb = SAb[..., d:]
    return SAb[..., :d], (Sb if b.ndim == 2 else Sb[..., 0])
