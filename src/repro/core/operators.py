"""`SketchOp`: the paper's sketch family as composable linear operators.

Every sketch ``S ∈ R^{m×n}`` in the repo used to exist only as a *function*
``(key, A) -> S @ A`` dispatched through a string-keyed if-chain. This module turns
each kind into a frozen linear-operator object built once from ``(SketchSpec, key, n)``
and exposing the full operator calculus the pipeline needs:

  * ``apply(A)``                 — ``S @ A`` (fast path per kind; Pallas kernel when
                                   ``spec.use_kernel`` and one exists),
  * ``adjoint(Y)``               — ``Sᵀ @ Y`` without ever materializing S (scatter for
                                   sampling sketches, FWHT for SRHT, gather for SJLT,
                                   streamed counter-RNG tiles for Gaussian),
  * ``apply_blocked(A, block_rows=...)`` — a ``lax.scan`` over row tiles of A, so ``n``
                                   can exceed device memory: each sketch is a sum /
                                   gather over row blocks and tile ``(i, j)`` of the
                                   random S is a pure function of ``(key, i, j)``
                                   (counter RNG, shared with ``repro.kernels``),
  * ``materialize()``            — explicit S for tests / tiny problems.

A registry (``@register(kind)`` → ``make_operator``) replaces every if-chain dispatch,
including the ``use_kernel`` routing into the Pallas kernels. Multi-worker callers use

  * :func:`apply_batched` — vmap ``q`` independent sketches over a *single* read of A
    (Algorithm 1's master-sketch mode, IHS's per-iteration sketches, head fitting),
  * :func:`sketch_data_batched` — the batched ``(S_k A, S_k b)`` pairs of Algorithm 1.

Randomness contract
-------------------
All per-element randomness is counter-based (threefry2x32 from ``repro.kernels.common``):
entry/row parameters are pure functions of ``(key, global index)``. This is what makes
``apply_blocked`` produce bit-comparable results for *any* block size, and what lets
the Pallas Gaussian/SJLT kernels draw the *same* S as the pure-jnp paths. Only the
O(m) row-sampling draws (uniform/leverage/SRHT row picks, hybrid's row subset) use
ordinary ``jax.random`` calls — they are tiny and never need streaming.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import sketches as sk
from repro.kernels import common as kcommon

# Default row-tile for blocked/streamed application. 4096 rows × 512 cols of f32 is
# 8 MiB — comfortably inside a v5e core's VMEM budget alongside the (m, block) S tile.
DEFAULT_BLOCK_ROWS = 4096


# ----------------------------------------------------------------------- registry

_REGISTRY: Dict[str, type] = {}


def register(kind: str) -> Callable[[type], type]:
    """Class decorator: make ``kind`` constructible through :func:`make_operator`."""

    def deco(cls: type) -> type:
        _REGISTRY[kind] = cls
        return cls

    return deco


def registered_kinds() -> tuple:
    return tuple(sorted(_REGISTRY))


def make_operator(
    spec: sk.SketchSpec,
    key: jax.Array,
    n: int,
    *,
    scores: Optional[jax.Array] = None,
) -> "SketchOp":
    """Build the frozen ``S ∈ R^{m×n}`` described by ``spec`` from ``key``.

    ``scores``: leverage scores (required for ``kind="leverage"``, ignored otherwise);
    data-dependent sketches must be given their data statistics explicitly so the
    resulting object is a *fixed* linear operator.
    """
    try:
        cls = _REGISTRY[spec.kind]
    except KeyError:
        raise ValueError(
            f"no SketchOp registered for kind {spec.kind!r}; known: {registered_kinds()}"
        ) from None
    return cls.build(spec, key, n, scores=scores)


# --------------------------------------------------------------------- shape utils


def _to_2d(X: jax.Array, rows: int):
    """View (rows, ...) as (rows, k); returns the 2-D view and the trailing shape."""
    if X.shape[0] != rows:
        raise ValueError(f"operator expects leading dim {rows}, got shape {X.shape}")
    return X.reshape(rows, -1), X.shape[1:]


def _from_2d(Y2: jax.Array, batch: tuple) -> jax.Array:
    return Y2.reshape((Y2.shape[0],) + batch)


def _scan_row_blocks(A2: jax.Array, n: int, block_rows: int, init: jax.Array, reducer):
    """Shared blocked-streaming scaffold: ``lax.scan`` of ``reducer(acc, j0, A_blk)``
    over zero-padded f32 row tiles of A2 (2-D). Zero rows beyond n contribute
    nothing to any registered reducer (matmul against zeros / gather of zeros /
    scatter of zeros), so no masking is needed."""
    bs = max(1, min(block_rows, n))
    nb = -(-n // bs)
    if nb * bs != n:
        A2 = jnp.pad(A2, ((0, nb * bs - n), (0, 0)))
    blocks = A2.reshape(nb, bs, A2.shape[1]).astype(jnp.float32)
    j0s = jnp.arange(nb, dtype=jnp.int32) * bs

    def body(acc, xs):
        j0, Ab = xs
        return reducer(acc, j0, Ab), None

    acc, _ = jax.lax.scan(body, init, (j0s, blocks))
    return acc


def _gather_rows_reducer(rows: jax.Array):
    """Reducer accumulating ``A[rows]`` from row blocks: O(len(rows)·k) per block
    (a mask-and-gather), not a dense one-hot matmul."""

    def reducer(acc, j0, Ab):
        local = rows - j0
        in_blk = (local >= 0) & (local < Ab.shape[0])
        idx = jnp.clip(local, 0, Ab.shape[0] - 1)
        return acc + jnp.where(in_blk[:, None], jnp.take(Ab, idx, axis=0), 0.0)

    return reducer


# -------------------------------------------------------------------------- base


@dataclasses.dataclass(frozen=True)
class SketchOp:
    """Frozen linear operator S ∈ R^{m×n} (base class).

    Subclasses either implement :meth:`columns` — an arbitrary column block of S,
    valid for traced start offsets — and inherit generic blocked apply/adjoint, or
    override the generic methods with cheaper structure-aware code (SJLT, hybrid).
    """

    spec: sk.SketchSpec
    key: jax.Array
    n: int

    @property
    def m(self) -> int:
        return self.spec.m

    @property
    def shape(self) -> tuple:
        return (self.m, self.n)

    # -- construction ------------------------------------------------------------

    @classmethod
    def build(cls, spec, key, n, *, scores=None) -> "SketchOp":
        raise NotImplementedError

    # -- required tile primitive --------------------------------------------------

    def columns(self, j0, block: int) -> jax.Array:
        """``S[:, j0 : j0+block]`` as an (m, block) tile. ``j0`` may be traced.

        Column indices ≥ n are permitted (blocked application pads A's rows with
        zeros, so out-of-range columns multiply zeros and contribute nothing); the
        values there only need to be finite.
        """
        raise NotImplementedError(f"{type(self).__name__} does not expose S tiles")

    # -- operator calculus --------------------------------------------------------

    def apply(self, A: jax.Array) -> jax.Array:
        """``S @ A`` for A of shape (n, ...). Default: one full-width tile."""
        A2, batch = _to_2d(A, self.n)
        out = (self.columns(0, self.n) @ A2.astype(jnp.float32)).astype(A.dtype)
        return _from_2d(out, batch)

    def apply_blocked(
        self, A: jax.Array, *, block_rows: int = DEFAULT_BLOCK_ROWS
    ) -> jax.Array:
        """``S @ A`` streamed as a ``lax.scan`` over row tiles of A.

        Peak live memory is O(block_rows · k + m · k) instead of O(n · k): the
        sketch never needs all of A resident. Matches :meth:`apply` to float
        tolerance for any ``block_rows`` (including ones that don't divide n).
        """
        A2, batch = _to_2d(A, self.n)
        acc = _scan_row_blocks(
            A2,
            self.n,
            block_rows,
            jnp.zeros((self.m, A2.shape[1]), jnp.float32),
            lambda acc, j0, Ab: acc + self.columns(j0, Ab.shape[0]) @ Ab,
        )
        return _from_2d(acc.astype(A.dtype), batch)

    def adjoint(self, Y: jax.Array, *, block_rows: int = DEFAULT_BLOCK_ROWS) -> jax.Array:
        """``Sᵀ @ Y`` for Y of shape (m, ...), streamed over column tiles of S."""
        Y2, batch = _to_2d(Y, self.m)
        Yf = Y2.astype(jnp.float32)
        bs = max(1, min(block_rows, self.n))
        nb = -(-self.n // bs)
        j0s = jnp.arange(nb, dtype=jnp.int32) * bs

        def body(_, j0):
            return None, self.columns(j0, bs).T @ Yf  # (bs, k)

        _, outs = jax.lax.scan(body, None, j0s)
        out = outs.reshape(nb * bs, Yf.shape[1])[: self.n]
        return _from_2d(out.astype(Y.dtype), batch)

    def materialize(self, dtype=jnp.float32) -> jax.Array:
        """Explicit S ∈ R^{m×n} (tests / small problems only)."""
        return self.apply(jnp.eye(self.n, dtype=dtype))


# ----------------------------------------------------------------------- gaussian


@register("gaussian")
@dataclasses.dataclass(frozen=True)
class GaussianOp(SketchOp):
    """i.i.d. N(0, 1/m) entries from the counter stream: S[i, j] = f(key, i, j).

    The exact same stream the RNG-fused Pallas kernel generates tile-by-tile
    (``repro.kernels.gaussian``), so the kernel path, the jnp path, blocked
    streaming, and the adjoint all agree on S.
    """

    k0: jax.Array = None
    k1: jax.Array = None

    @classmethod
    def build(cls, spec, key, n, *, scores=None):
        k0, k1 = kcommon.key_to_words(key)
        return cls(spec=spec, key=key, n=n, k0=k0, k1=k1)

    def columns(self, j0, block: int) -> jax.Array:
        rows = jax.lax.broadcasted_iota(jnp.uint32, (self.m, block), 0)
        cols = jnp.uint32(j0) + jax.lax.broadcasted_iota(jnp.uint32, (self.m, block), 1)
        z = kcommon.counter_normal(self.k0, self.k1, rows, cols)
        return z * jnp.float32(1.0 / math.sqrt(self.m))

    def apply(self, A: jax.Array) -> jax.Array:
        if self.spec.use_kernel:
            from repro.kernels.gaussian import ops as gops

            A2, batch = _to_2d(A, self.n)
            return _from_2d(gops.gaussian_sketch(self.key, A2, self.m), batch)
        return super().apply(A)


# -------------------------------------------------------------------------- srht


@register("srht")
@dataclasses.dataclass(frozen=True)
class SRHTOp(SketchOp):
    """Randomized Hadamard (ROS): S = (1/√m) · P · H · D on the 2^⌈log n⌉ padding.

    ``apply`` uses the O(n log n) FWHT (Pallas kernel when requested); ``columns``
    builds Hadamard tiles H[r, j] = (−1)^popcount(r & j) on the fly, which is what
    makes blocked/streamed application possible without the full transform.
    """

    kd0: jax.Array = None  # sign-counter key words (D diagonal)
    kd1: jax.Array = None
    rows: jax.Array = None  # (m,) sampled Hadamard rows, with replacement
    n_pad: int = 0

    @classmethod
    def build(cls, spec, key, n, *, scores=None):
        n_pad = sk.next_pow2(n)
        kd, kp = jax.random.split(key)
        kd0, kd1 = kcommon.key_to_words(kd)
        rows = jax.random.randint(kp, (spec.m,), 0, n_pad)
        return cls(spec=spec, key=key, n=n, kd0=kd0, kd1=kd1, rows=rows, n_pad=n_pad)

    def _signs(self, j: jax.Array) -> jax.Array:
        """Rademacher diagonal D at (possibly traced) coordinate(s) j."""
        return kcommon.counter_rademacher(self.kd0, self.kd1, j.astype(jnp.uint32), jnp.uint32(0))

    def apply(self, A: jax.Array) -> jax.Array:
        A2, batch = _to_2d(A, self.n)
        DA = A2.astype(jnp.float32) * self._signs(jnp.arange(self.n))[:, None]
        if self.n_pad != self.n:
            DA = jnp.pad(DA, ((0, self.n_pad - self.n), (0, 0)))
        if self.spec.use_kernel:
            from repro.kernels.fwht import ops as fops

            HDA = fops.fwht(DA)
        else:
            HDA = sk._fwht(DA)
        out = jnp.take(HDA, self.rows, axis=0) * jnp.float32(1.0 / math.sqrt(self.m))
        return _from_2d(out.astype(A.dtype), batch)

    def columns(self, j0, block: int) -> jax.Array:
        j = jnp.uint32(j0) + jnp.arange(block, dtype=jnp.uint32)
        # Sylvester closed form: H[r, j] = (−1)^popcount(r & j) — no transform needed.
        parity = jax.lax.population_count(self.rows.astype(jnp.uint32)[:, None] & j[None, :])
        h = (1 - 2 * (parity & jnp.uint32(1)).astype(jnp.int32)).astype(jnp.float32)
        return h * self._signs(j)[None, :] * jnp.float32(1.0 / math.sqrt(self.m))

    def adjoint(self, Y: jax.Array, *, block_rows: int = DEFAULT_BLOCK_ROWS) -> jax.Array:
        Y2, batch = _to_2d(Y, self.m)
        # Sᵀ = (1/√m) · D · Hᵀ · Pᵀ with H symmetric; Pᵀ is scatter-add (P repeats rows).
        Z = jnp.zeros((self.n_pad, Y2.shape[1]), jnp.float32).at[self.rows].add(
            Y2.astype(jnp.float32)
        )
        HZ = sk._fwht(Z)[: self.n]
        out = HZ * self._signs(jnp.arange(self.n))[:, None] * jnp.float32(1.0 / math.sqrt(self.m))
        return _from_2d(out.astype(Y.dtype), batch)


# ------------------------------------------------------------------ row sampling


@register("uniform")
@dataclasses.dataclass(frozen=True)
class UniformOp(SketchOp):
    """Uniform row sampling scaled by √(n/m) so E[SᵀS] = I."""

    rows: jax.Array = None  # (m,)

    @classmethod
    def build(cls, spec, key, n, *, scores=None):
        if spec.replacement:
            rows = jax.random.randint(key, (spec.m,), 0, n)
        else:
            # Gumbel top-k == sampling without replacement, jit-friendly.
            g = jax.random.gumbel(key, (n,))
            rows = jax.lax.top_k(g, spec.m)[1]
        return cls(spec=spec, key=key, n=n, rows=rows)

    @property
    def _scale(self) -> float:
        return math.sqrt(self.n / self.m)

    def apply(self, A: jax.Array) -> jax.Array:
        return jnp.take(A, self.rows, axis=0) * jnp.asarray(self._scale, A.dtype)

    def columns(self, j0, block: int) -> jax.Array:
        j = jnp.int32(j0) + jnp.arange(block, dtype=jnp.int32)
        onehot = (self.rows[:, None] == j[None, :]).astype(jnp.float32)
        return onehot * jnp.float32(self._scale)

    def apply_blocked(
        self, A: jax.Array, *, block_rows: int = DEFAULT_BLOCK_ROWS
    ) -> jax.Array:
        A2, batch = _to_2d(A, self.n)
        acc = _scan_row_blocks(
            A2,
            self.n,
            block_rows,
            jnp.zeros((self.m, A2.shape[1]), jnp.float32),
            _gather_rows_reducer(self.rows),
        )
        return _from_2d((acc * jnp.float32(self._scale)).astype(A.dtype), batch)

    def adjoint(self, Y: jax.Array, *, block_rows: int = DEFAULT_BLOCK_ROWS) -> jax.Array:
        Y2, batch = _to_2d(Y, self.m)
        out = jnp.zeros((self.n, Y2.shape[1]), Y2.dtype).at[self.rows].add(Y2)
        return _from_2d(out * jnp.asarray(self._scale, Y.dtype), batch)


@register("leverage")
@dataclasses.dataclass(frozen=True)
class LeverageOp(SketchOp):
    """Leverage-score sampling: P[row j] ∝ ℓ_j, kept row scaled by 1/√(m·p_j)."""

    rows: jax.Array = None  # (m,)
    scales: jax.Array = None  # (m,)

    @classmethod
    def build(cls, spec, key, n, *, scores=None):
        if scores is None:
            raise ValueError(
                "leverage sketches are data-dependent: pass scores= to make_operator "
                "(e.g. sketches.leverage_scores(A)) so the operator is fixed"
            )
        p = scores / jnp.sum(scores)
        rows = jax.random.categorical(key, jnp.log(p + 1e-30), shape=(spec.m,))
        scales = 1.0 / jnp.sqrt(spec.m * jnp.take(p, rows))
        return cls(spec=spec, key=key, n=n, rows=rows, scales=scales)

    def apply(self, A: jax.Array) -> jax.Array:
        scl = self.scales.astype(A.dtype)
        return jnp.take(A, self.rows, axis=0) * scl.reshape((self.m,) + (1,) * (A.ndim - 1))

    def columns(self, j0, block: int) -> jax.Array:
        j = jnp.int32(j0) + jnp.arange(block, dtype=jnp.int32)
        onehot = (self.rows[:, None] == j[None, :]).astype(jnp.float32)
        return onehot * self.scales.astype(jnp.float32)[:, None]

    def apply_blocked(
        self, A: jax.Array, *, block_rows: int = DEFAULT_BLOCK_ROWS
    ) -> jax.Array:
        A2, batch = _to_2d(A, self.n)
        acc = _scan_row_blocks(
            A2,
            self.n,
            block_rows,
            jnp.zeros((self.m, A2.shape[1]), jnp.float32),
            _gather_rows_reducer(self.rows),
        )
        return _from_2d((acc * self.scales.astype(jnp.float32)[:, None]).astype(A.dtype), batch)

    def adjoint(self, Y: jax.Array, *, block_rows: int = DEFAULT_BLOCK_ROWS) -> jax.Array:
        Y2, batch = _to_2d(Y, self.m)
        contrib = Y2 * self.scales.astype(Y2.dtype)[:, None]
        out = jnp.zeros((self.n, Y2.shape[1]), Y2.dtype).at[self.rows].add(contrib)
        return _from_2d(out, batch)


# -------------------------------------------------------------------------- sjlt


@register("sjlt")
@dataclasses.dataclass(frozen=True)
class SJLTOp(SketchOp):
    """Sparse JL: s nonzeros (±1/√s) per input coordinate, counter-derived per row.

    Row parameters come from :func:`repro.kernels.common.sjlt_counter_params`, the
    same draw the Pallas kernel consumes — kernel and jnp paths share S exactly.
    """

    k0: jax.Array = None
    k1: jax.Array = None

    @classmethod
    def build(cls, spec, key, n, *, scores=None):
        k0, k1 = kcommon.key_to_words(key)
        return cls(spec=spec, key=key, n=n, k0=k0, k1=k1)

    def _params(self, row_idx: jax.Array):
        return kcommon.sjlt_counter_params(self.k0, self.k1, row_idx, self.spec.s, self.m)

    def _segment_apply(self, A2: jax.Array, row_idx: jax.Array) -> jax.Array:
        buckets, signs = self._params(row_idx)
        r, s = buckets.shape
        vals = (signs[..., None] * A2[:, None, :]).reshape(r * s, A2.shape[1])
        return jax.ops.segment_sum(vals, buckets.reshape(-1), num_segments=self.m)

    def apply(self, A: jax.Array) -> jax.Array:
        A2, batch = _to_2d(A, self.n)
        if self.spec.use_kernel:
            from repro.kernels.sjlt import ops as sops

            buckets, signs = self._params(jnp.arange(self.n))
            out = sops.sjlt_apply(A2, buckets, signs, self.m)
        else:
            out = self._segment_apply(A2.astype(jnp.float32), jnp.arange(self.n)).astype(A.dtype)
        return _from_2d(out, batch)

    def apply_blocked(
        self, A: jax.Array, *, block_rows: int = DEFAULT_BLOCK_ROWS
    ) -> jax.Array:
        A2, batch = _to_2d(A, self.n)
        acc = _scan_row_blocks(
            A2,
            self.n,
            block_rows,
            jnp.zeros((self.m, A2.shape[1]), jnp.float32),
            lambda acc, j0, Ab: acc
            + self._segment_apply(Ab, j0 + jnp.arange(Ab.shape[0], dtype=jnp.int32)),
        )
        return _from_2d(acc.astype(A.dtype), batch)

    def adjoint(self, Y: jax.Array, *, block_rows: int = DEFAULT_BLOCK_ROWS) -> jax.Array:
        Y2, batch = _to_2d(Y, self.m)
        buckets, signs = self._params(jnp.arange(self.n))  # (n, s)
        gathered = jnp.take(Y2.astype(jnp.float32), buckets, axis=0)  # (n, s, k)
        out = jnp.sum(gathered * signs[..., None], axis=1)
        return _from_2d(out.astype(Y.dtype), batch)


# ------------------------------------------------------------------------ hybrid


@register("hybrid")
@dataclasses.dataclass(frozen=True)
class HybridOp(SketchOp):
    """Paper §IV-D: uniform-sample m′ rows without replacement (what a worker can
    afford to *read*), then an inner sketch m′ → m (what it can afford to *compute*).

    S = S_inner · U with U the scaled row-subset selector; the operator calculus
    composes: apply = inner∘gather, adjoint = scatter∘innerᵀ."""

    rows: jax.Array = None  # (m_prime,)
    inner: SketchOp = None

    @classmethod
    def build(cls, spec, key, n, *, scores=None):
        k1, k2 = jax.random.split(key)
        g = jax.random.gumbel(k1, (n,))
        rows = jax.lax.top_k(g, spec.m_prime)[1]
        inner_spec = sk.SketchSpec(spec.inner, spec.m, s=spec.s, use_kernel=spec.use_kernel)
        inner = make_operator(inner_spec, k2, spec.m_prime)
        return cls(spec=spec, key=key, n=n, rows=rows, inner=inner)

    @property
    def _scale(self) -> float:
        return math.sqrt(self.n / self.spec.m_prime)

    def apply(self, A: jax.Array) -> jax.Array:
        sampled = jnp.take(A, self.rows, axis=0) * jnp.asarray(self._scale, A.dtype)
        return self.inner.apply(sampled)

    def apply_blocked(
        self, A: jax.Array, *, block_rows: int = DEFAULT_BLOCK_ROWS
    ) -> jax.Array:
        A2, batch = _to_2d(A, self.n)
        # The m′×k intermediate is exactly the "what a worker reads" budget — it is
        # the one thing hybrid sketching keeps resident while streaming over n.
        sampled = _scan_row_blocks(
            A2,
            self.n,
            block_rows,
            jnp.zeros((self.spec.m_prime, A2.shape[1]), jnp.float32),
            _gather_rows_reducer(self.rows),
        )
        out = self.inner.apply(sampled * jnp.float32(self._scale))
        return _from_2d(out.astype(A.dtype), batch)

    def adjoint(self, Y: jax.Array, *, block_rows: int = DEFAULT_BLOCK_ROWS) -> jax.Array:
        Y2, batch = _to_2d(Y, self.m)
        z = self.inner.adjoint(Y2)  # (m_prime, k)
        out = jnp.zeros((self.n, z.shape[1]), z.dtype).at[self.rows].add(z)
        return _from_2d(out * jnp.asarray(self._scale, Y.dtype), batch)


# --------------------------------------------------------- functional entry points


def _scores_for(spec: sk.SketchSpec, A: jax.Array, scores) -> Optional[jax.Array]:
    if spec.kind == "leverage" and scores is None:
        return sk.leverage_scores(A.reshape(A.shape[0], -1))
    return scores


def apply(spec: sk.SketchSpec, key: jax.Array, A: jax.Array, *, scores=None) -> jax.Array:
    """``S @ A`` — the registry-dispatched replacement for the old if-chain."""
    scores = _scores_for(spec, A, scores)
    return make_operator(spec, key, A.shape[0], scores=scores).apply(A)


def apply_blocked(
    spec: sk.SketchSpec,
    key: jax.Array,
    A: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    scores=None,
) -> jax.Array:
    """``S @ A`` streamed over row tiles (out-of-core n)."""
    scores = _scores_for(spec, A, scores)
    return make_operator(spec, key, A.shape[0], scores=scores).apply_blocked(
        A, block_rows=block_rows
    )


def apply_batched(
    spec: sk.SketchSpec, keys: jax.Array, A: jax.Array, *, scores=None
) -> jax.Array:
    """All ``q`` workers' sketches ``(S_k A)_k`` in one pass over A.

    ``keys``: (q,)-batched PRNG keys (e.g. ``prng.worker_keys``). vmapping the
    per-key operator means A is read once and the q projections batch onto the
    MXU, instead of q separate passes. Data-dependent statistics (leverage
    scores) are computed once and shared — each worker still draws its own rows.
    Returns a (q, m, ...) stack.
    """
    scores = _scores_for(spec, A, scores)

    def one(k):
        return make_operator(spec, k, A.shape[0], scores=scores).apply(A)

    if spec.use_kernel:
        # pallas_call batching in interpret mode is unreliable; sequential map still
        # reuses the single resident copy of A.
        return jax.lax.map(one, keys)
    return jax.vmap(one)(keys)


def sketch_data_batched(
    spec: sk.SketchSpec, keys: jax.Array, A: jax.Array, b: jax.Array
) -> tuple:
    """Batched Algorithm-1 master step: ``(S_k A, S_k b)`` for every worker key,
    sketching ``[A | b]`` jointly so each worker's pair shares its S."""
    bm = b if b.ndim == 2 else b[:, None]
    d = A.shape[1]
    SAb = apply_batched(spec, keys, jnp.concatenate([A, bm], axis=1))
    Sb = SAb[..., d:]
    return SAb[..., :d], (Sb if b.ndim == 2 else Sb[..., 0])
