"""Shared neural-net layers: norms, rotary embeddings, FFNs, embeddings.

Everything is functional: ``init_*`` builds a param dict, the apply functions are pure.
Parameters are plain nested dicts of jnp arrays so that checkpointing, sharding rules
and lax.scan stacking stay trivial.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def _dense_init(key, shape, in_axis_size, dtype):
    scale = 1.0 / math.sqrt(in_axis_size)
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ------------------------------------------------------------------ norms


def init_rmsnorm(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dtype)


# ------------------------------------------------------------------ rotary


def rope_angles(positions: jax.Array, dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """cos/sin tables for rotary embedding. positions: (...,) int; dim must be even.
    Returns (cos, sin) of shape positions.shape + (dim//2,)."""
    half = dim // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array, fraction: float = 1.0) -> jax.Array:
    """Rotate the first ``fraction`` of the head dim of x: (..., S, H, hd).

    cos/sin: (..., S, rot/2) broadcast over heads. ChatGLM-style 2d rope is
    fraction=0.5 (second half of the head dim passes through unrotated).
    """
    hd = x.shape[-1]
    rot = int(hd * fraction)
    rot -= rot % 2
    if rot == 0:
        return x
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    xr = x_rot.reshape(*x_rot.shape[:-1], rot // 2, 2)
    x1, x2 = xr[..., 0], xr[..., 1]
    c = cos[..., None, : rot // 2]
    s = sin[..., None, : rot // 2]
    # rotate in f32 (cos/sin precision), return in the activation dtype
    y1 = x1 * c - x2 * s
    y2 = x1 * s + x2 * c
    y = jnp.stack([y1, y2], axis=-1).reshape(x_rot.shape).astype(x.dtype)
    return jnp.concatenate([y, x_pass], axis=-1) if rot < hd else y


# ------------------------------------------------------------------ FFN (SwiGLU)


def init_swiglu(key: jax.Array, d: int, f: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": _dense_init(k1, (d, f), d, dtype),
        "w_up": _dense_init(k2, (d, f), d, dtype),
        "w_down": _dense_init(k3, (f, d), f, dtype),
    }


def swiglu(params: dict, x: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, params["w_gate"])
    u = jnp.einsum("...d,df->...f", x, params["w_up"])
    h = jax.nn.silu(g) * u
    return jnp.einsum("...f,fd->...d", h, params["w_down"])


# ------------------------------------------------------------------ embeddings


def init_embedding(key: jax.Array, vocab: int, d: int, dtype) -> dict:
    return {"table": (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)}


def embed(params: dict, tokens: jax.Array) -> jax.Array:
    return jnp.take(params["table"], tokens, axis=0)


def init_unembed(key: jax.Array, d: int, vocab: int, dtype) -> dict:
    return {"w": _dense_init(key, (d, vocab), d, dtype)}


def unembed(params: dict, x: jax.Array) -> jax.Array:
    return jnp.einsum("...d,dv->...v", x, params["w"])


# ------------------------------------------------------------------ losses


def cross_entropy_loss(
    logits: jax.Array, labels: jax.Array, mask: Optional[jax.Array] = None
) -> jax.Array:
    """Mean next-token CE. logits: (..., V) any dtype; computed in f32."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        m = mask.astype(jnp.float32)
        return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)
    return jnp.mean(nll)
