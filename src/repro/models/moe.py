"""Mixture-of-Experts FFN: top-k routing with sort-based capacity dispatch.

TPU adaptation: GShard's one-hot dispatch einsum materializes a (tokens, E, capacity)
tensor — at our shapes that is >10¹² elements, a non-starter. We instead dispatch by
*sorting* each sequence's (token, expert) assignments by expert id and slicing fixed
capacity windows per expert: gathers and matmuls only, O(S·k·log) sort cost, no giant
one-hots. The group axis is the sequence (training/prefill) or the whole batch
(decode), so routing never crosses the data-parallel shard boundary.

Capacity drops follow GShard: tokens beyond an expert's capacity in a group are
dropped (their combine weight is 0 and the residual path carries them). The auxiliary
load-balance loss (Switch/GShard form) discourages systematic drops.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp


def init_moe(key, d: int, f: int, num_experts: int, dtype) -> dict:
    kr, kg, ku, kd = jax.random.split(key, 4)
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(f)
    return {
        "router": (jax.random.normal(kr, (d, num_experts)) * s_in).astype(dtype),
        "w_gate": (jax.random.normal(kg, (num_experts, d, f)) * s_in).astype(dtype),
        "w_up": (jax.random.normal(ku, (num_experts, d, f)) * s_in).astype(dtype),
        "w_down": (jax.random.normal(kd, (num_experts, f, d)) * s_out).astype(dtype),
    }


def _route(params, x, num_experts: int, top_k: int):
    """x: (G, T, d) -> gate weights (G, T, k), expert ids (G, T, k), aux loss."""
    logits = jnp.einsum("gtd,de->gte", x, params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)  # (G, T, k)
    gate_vals = gate_vals / jnp.maximum(jnp.sum(gate_vals, -1, keepdims=True), 1e-9)
    # Switch-style aux loss: E * mean(fraction_routed * mean_prob)
    T = x.shape[1]
    frac = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_ids[..., 0], num_experts), axis=1) / T, axis=0
    )
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux = num_experts * jnp.sum(frac * mean_prob)
    return gate_vals, expert_ids, aux


def moe_forward(
    params: dict,
    x: jax.Array,
    *,
    num_experts: int,
    top_k: int,
    capacity_factor: float = 1.25,
    rules=None,
) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) (decode: (1, B, d) — the batch is the group). Returns (out, aux).

    ``rules``: sharding rules for the per-expert intermediates — without explicit
    constraints GSPMD hits 'involuntary full rematerialization' on the gather/scatter
    laneage and all-reduces replicated f32 copies of every expert's activations
    (measured on grok-1: 15.6 TB of wire per step)."""
    from repro.distributed.sharding import constrain

    G, T, d = x.shape
    E, k = num_experts, top_k
    capacity = max(1, int(capacity_factor * k * T / E))
    capacity = min(capacity, T * k)

    gate_vals, expert_ids, aux = _route(params, x, E, k)

    # Flatten the k assignments into one token stream per group: (G, T*k)
    flat_expert = expert_ids.reshape(G, T * k)
    flat_gate = gate_vals.reshape(G, T * k)
    flat_tok = jnp.broadcast_to(jnp.arange(T)[:, None], (T, k)).reshape(T * k)
    flat_tok = jnp.broadcast_to(flat_tok[None], (G, T * k))

    # Stable sort by expert id: tokens of expert e occupy one contiguous run.
    order = jnp.argsort(flat_expert, axis=1, stable=True)
    sorted_expert = jnp.take_along_axis(flat_expert, order, axis=1)
    sorted_tok = jnp.take_along_axis(flat_tok, order, axis=1)
    sorted_gate = jnp.take_along_axis(flat_gate, order, axis=1)

    counts = jnp.sum(jax.nn.one_hot(flat_expert, E, dtype=jnp.int32), axis=1)  # (G, E)
    starts = jnp.concatenate(
        [jnp.zeros((G, 1), jnp.int32), jnp.cumsum(counts, axis=1)[:, :-1]], axis=1
    )

    out = jnp.zeros_like(x)
    slot = jnp.arange(capacity)
    for e in range(E):  # static unroll: E is small (8)
        idx = starts[:, e : e + 1] + slot[None, :]          # (G, C)
        idx = jnp.minimum(idx, T * k - 1)
        keep = slot[None, :] < jnp.minimum(counts[:, e : e + 1], capacity)
        tok_e = jnp.take_along_axis(sorted_tok, idx, axis=1)         # (G, C)
        gate_e = jnp.take_along_axis(sorted_gate, idx, axis=1) * keep
        x_e = jnp.take_along_axis(x, tok_e[..., None], axis=1)       # (G, C, d)
        x_e = constrain(x_e, rules, "dp", None, None)
        g = jnp.einsum("gcd,df->gcf", x_e, params["w_gate"][e])
        g = constrain(g, rules, "dp", None, "tensor")
        u = jnp.einsum("gcd,df->gcf", x_e, params["w_up"][e])
        u = constrain(u, rules, "dp", None, "tensor")
        y = jnp.einsum("gcf,fd->gcd", jax.nn.silu(g) * u, params["w_down"][e])
        y = constrain(y, rules, "dp", None, None)
        y = y * gate_e[..., None].astype(y.dtype)
        out = jax.vmap(lambda o, t, v: o.at[t].add(v))(out, tok_e, y)
    return out, aux


def moe_dense_fallback(params, x, *, num_experts: int, top_k: int):
    """Reference path: compute every expert densely, combine with gate weights.
    O(E/k) more FLOPs — used by tests to validate the dispatch path."""
    G, T, d = x.shape
    gate_vals, expert_ids, aux = _route(params, x, num_experts, top_k)
    g = jnp.einsum("gtd,edf->getf", x, params["w_gate"])
    u = jnp.einsum("gtd,edf->getf", x, params["w_up"])
    y = jnp.einsum("getf,efd->getd", jax.nn.silu(g) * u, params["w_down"])  # (G,E,T,d)
    combine = jnp.sum(
        jax.nn.one_hot(expert_ids, num_experts, dtype=y.dtype)
        * gate_vals[..., None].astype(y.dtype),
        axis=2,
    )  # (G, T, E)
    out = jnp.einsum("gte,getd->gtd", combine, y)
    return out, aux
