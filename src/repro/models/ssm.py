"""Mamba-1 selective SSM block (falcon-mamba; also the SSM branch of hymba).

The recurrence h_t = Ā_t h_{t-1} + B̄_t u_t (diagonal Ā) is evaluated with a *chunked
associative scan*: within chunks of ``chunk`` timesteps a parallel associative scan
(O(log chunk) depth, MXU/VPU friendly), across chunks a sequential lax.scan carrying
only the (B, d_inner, state) boundary state. This bounds the scan's materialized
intermediates to O(chunk) timesteps — the full-sequence associative scan at 32k×8192×16
would hold log₂(32k) ≈ 15 copies of a multi-GiB tensor.

Decode is the O(1) recurrent update — the whole point of SSMs for long_500k.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp


def init_mamba(key, d: int, *, d_inner: int, state: int, d_conv: int, dt_rank: int, dtype) -> dict:
    ks = jax.random.split(key, 6)
    s_d = 1.0 / math.sqrt(d)
    s_i = 1.0 / math.sqrt(d_inner)
    s_r = 1.0 / math.sqrt(dt_rank)
    # S4D-real initialization for A: A = -(1..state), broadcast over channels.
    A = jnp.broadcast_to(jnp.arange(1, state + 1, dtype=jnp.float32), (d_inner, state))
    return {
        "in_proj": (jax.random.normal(ks[0], (d, 2 * d_inner)) * s_d).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (d_conv, d_inner)) * 0.5).astype(dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "x_proj": (jax.random.normal(ks[2], (d_inner, dt_rank + 2 * state)) * s_i).astype(dtype),
        "dt_proj_w": (jax.random.normal(ks[3], (dt_rank, d_inner)) * s_r).astype(dtype),
        "dt_proj_b": jnp.full((d_inner,), math.log(math.e**0.01 - 1), dtype),  # softplus⁻¹(0.01)
        "A_log": jnp.log(A).astype(jnp.float32),
        "D": jnp.ones((d_inner,), dtype),
        "out_proj": (jax.random.normal(ks[4], (d_inner, d)) * s_i).astype(dtype),
    }


def _causal_conv(u: jax.Array, w: jax.Array, b: jax.Array, init_state=None):
    """Depthwise causal conv. u: (B, T, C); w: (K, C). init_state: (B, K-1, C)."""
    K = w.shape[0]
    if init_state is None:
        u_pad = jnp.pad(u, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        u_pad = jnp.concatenate([init_state.astype(u.dtype), u], axis=1)
    out = jnp.zeros_like(u)
    for i in range(K):  # K is 4: static unroll beats conv_general for depthwise-1d
        out = out + u_pad[:, i : i + u.shape[1]] * w[K - 1 - i][None, None, :]
    return out + b[None, None, :]


def _ssm_scan_chunked(dA: jax.Array, dBu: jax.Array, h0: jax.Array, chunk: int):
    """h_t = dA_t ⊙ h_{t-1} + dBu_t, diagonal. dA/dBu: (B, T, C, N); h0: (B, C, N).
    Returns (hs (B, T, C, N), h_T). (Reference path — kept for tests; the fused
    production path below never materializes the (B, T, C, N) inputs/outputs.)"""
    B, T, C, N = dA.shape
    n_chunks = -(-T // chunk)
    T_pad = n_chunks * chunk
    if T_pad != T:
        dA = jnp.pad(dA, ((0, 0), (0, T_pad - T), (0, 0), (0, 0)), constant_values=1.0)
        dBu = jnp.pad(dBu, ((0, 0), (0, T_pad - T), (0, 0), (0, 0)))
    dA_c = dA.reshape(B, n_chunks, chunk, C, N).transpose(1, 0, 2, 3, 4)
    dBu_c = dBu.reshape(B, n_chunks, chunk, C, N).transpose(1, 0, 2, 3, 4)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a2 * a1, a2 * b1 + b2

    def chunk_step(h, xs):
        dA_i, dBu_i = xs  # (B, chunk, C, N)
        a, bb = jax.lax.associative_scan(combine, (dA_i, dBu_i), axis=1)
        hs = a * h[:, None] + bb                  # inject boundary state
        return hs[:, -1], hs

    hT, hs = jax.lax.scan(chunk_step, h0, (dA_c, dBu_c))
    hs = hs.transpose(1, 0, 2, 3, 4).reshape(B, T_pad, C, N)[:, :T]
    return hs, hT


def _ssm_scan_fused(u, dt, Bmat, Cmat, A, h0, chunk: int):
    """Fused chunked scan: per chunk, build dA/dBu from (dt, B, u), run the
    associative scan, and contract with C immediately — nothing (B, T, C, N)-shaped
    ever exists (§Perf: the falcon-mamba memory term was 83 s of HBM traffic from
    exactly those tensors). u/dt: (B, T, C); Bmat/Cmat: (B, T, N); A: (C, N).
    Returns (y (B, T, C) f32, h_T (B, C, N))."""
    B, T, C = u.shape
    N = A.shape[1]
    n_chunks = -(-T // chunk)
    T_pad = n_chunks * chunk
    if T_pad != T:
        pad = ((0, 0), (0, T_pad - T), (0, 0))
        u = jnp.pad(u, pad)
        dt = jnp.pad(dt, pad)
        Bmat = jnp.pad(Bmat, pad)
        Cmat = jnp.pad(Cmat, pad)

    def cview(x):  # (B, T_pad, ...) -> (n_chunks, B, chunk, ...)
        return x.reshape((B, n_chunks, chunk) + x.shape[2:]).transpose(1, 0, 2, *range(3, x.ndim + 1))

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a2 * a1, a2 * b1 + b2

    def chunk_step(h, xs):
        u_i, dt_i, B_i, C_i = xs                     # (B, chunk, C) / (B, chunk, N)
        dtf = dt_i.astype(jnp.float32)
        dA_i = jnp.exp(dtf[..., None] * A[None, None])                       # (B,c,C,N)
        dBu_i = (dtf * u_i.astype(jnp.float32))[..., None] * B_i.astype(jnp.float32)[:, :, None, :]
        a, bb = jax.lax.associative_scan(combine, (dA_i, dBu_i), axis=1)
        hs = a * h[:, None] + bb
        y_i = jnp.einsum("btcn,btn->btc", hs, C_i.astype(jnp.float32))
        return hs[:, -1], y_i

    hT, ys = jax.lax.scan(chunk_step, h0, (cview(u), cview(dt), cview(Bmat), cview(Cmat)))
    y = ys.transpose(1, 0, 2, 3).reshape(B, T_pad, C)[:, :T]
    return y, hT


def mamba_forward(
    params: dict,
    x: jax.Array,
    *,
    state: int,
    dt_rank: int,
    chunk: int = 128,
    return_state: bool = False,
):
    """Full-sequence mamba block. x: (B, T, d) -> (B, T, d).

    return_state=True additionally returns (conv_tail, h_T): the last K-1 pre-conv
    activations and the final SSM state — the decode cache after a batched prefill."""
    B, T, _ = x.shape
    xu = jnp.einsum("btd,de->bte", x, params["in_proj"])
    u_raw, z = jnp.split(xu, 2, axis=-1)                   # (B, T, d_inner) each
    u = u_raw
    u = jax.nn.silu(_causal_conv(u, params["conv_w"], params["conv_b"]))

    proj = jnp.einsum("btc,ce->bte", u, params["x_proj"])
    dt, Bmat, Cmat = jnp.split(proj, [dt_rank, dt_rank + state], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("btr,rc->btc", dt, params["dt_proj_w"]) + params["dt_proj_b"])
    A = -jnp.exp(params["A_log"])                          # (C, N)

    h0 = jnp.zeros((B, u.shape[-1], state), jnp.float32)
    y, hT = _ssm_scan_fused(u, dt, Bmat, Cmat, A, h0, chunk)
    y = y.astype(x.dtype)
    y = y + u * params["D"][None, None, :]
    y = y * jax.nn.silu(z)
    out = jnp.einsum("btc,cd->btd", y, params["out_proj"])
    if return_state:
        K = params["conv_w"].shape[0]
        tail = u_raw[:, -(K - 1):] if T >= K - 1 else jnp.pad(u_raw, ((0, 0), (K - 1 - T, 0), (0, 0)))
        return out, (tail, hT)
    return out


def mamba_decode(
    params: dict,
    x: jax.Array,
    conv_state: jax.Array,
    ssm_state: jax.Array,
    *,
    state: int,
    dt_rank: int,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token recurrent update. x: (B, 1, d); conv_state: (B, K-1, C);
    ssm_state: (B, C, N). Returns (out, new_conv_state, new_ssm_state)."""
    B = x.shape[0]
    xu = jnp.einsum("btd,de->bte", x, params["in_proj"])
    u, z = jnp.split(xu, 2, axis=-1)                       # (B, 1, C)

    window = jnp.concatenate([conv_state.astype(u.dtype), u], axis=1)  # (B, K, C)
    new_conv_state = window[:, 1:].astype(conv_state.dtype)
    K = params["conv_w"].shape[0]
    # window[K-1] is the current token; _causal_conv pairs u[t-j] with w[j], so the
    # tap order is reversed relative to the window's time order.
    u1 = jnp.einsum("bkc,kc->bc", window, params["conv_w"][::-1]) + params["conv_b"]
    u1 = jax.nn.silu(u1)                                   # (B, C)

    proj = jnp.einsum("bc,ce->be", u1, params["x_proj"])
    dt, Bv, Cv = jnp.split(proj, [dt_rank, dt_rank + state], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("br,rc->bc", dt, params["dt_proj_w"]) + params["dt_proj_b"])
    A = -jnp.exp(params["A_log"])
    dA = jnp.exp(dt.astype(jnp.float32)[..., None] * A[None])          # (B, C, N)
    dBu = (dt * u1).astype(jnp.float32)[..., None] * Bv.astype(jnp.float32)[:, None, :]
    new_ssm = dA * ssm_state + dBu
    y = jnp.einsum("bcn,bn->bc", new_ssm, Cv.astype(jnp.float32)).astype(x.dtype)
    y = y + u1 * params["D"][None, :]
    y = (y * jax.nn.silu(z[:, 0]))[:, None, :]
    out = jnp.einsum("btc,cd->btd", y, params["out_proj"])
    return out, new_conv_state, new_ssm.astype(ssm_state.dtype)
