"""Model zoo: functional layers + one assembly module covering all assigned archs."""
from repro.models.lm import (
    init_params,
    param_shapes,
    lm_loss,
    forward_logits,
    init_cache,
    cache_shapes,
    decode_step,
    prefill,
    layer_windows,
)
