"""Model assembly: every assigned architecture as one functional decoder stack.

One parameter layout, one forward, one decode — family differences (dense / MoE / SSM /
hybrid / enc-dec / VLM) are dispatch points inside the per-layer body. Layers are
*stacked* (every leaf gets a leading L axis, built with ``jax.vmap`` over per-layer
keys) and iterated with ``lax.scan`` so the HLO size is independent of depth — at
62-layer / 64-layer configs an unrolled stack would take minutes to compile and blow
the dry-run memory.

Positional note (documented hardware adaptation): whisper's learned absolute positions
and conv frontend are replaced by the precomputed-frame stub + RoPE on the decoder;
this keeps one rotary implementation across all ten archs.

Remat: each scan step is wrapped in ``jax.checkpoint`` (policy selectable) so training
activations are O(L · remat-residuals) instead of O(L · full-layer-intermediates).
The LM head loss is *chunked over the sequence* — logits at (B, S, 262k-vocab) never
materialize; each chunk's logits are recomputed in the backward pass.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import ShardingRules, constrain
from repro.models import attention, layers, moe as moe_lib, ssm as ssm_lib

PyTree = Any


def _dtype(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


@dataclasses.dataclass(frozen=True)
class ExecPlan:
    """Execution knobs, orthogonal to the architecture config.

    The *exec* plan controls real peak memory (chunk sizes bound flash/CE/SSM tiles,
    rolled scans reuse buffers). The *analysis* plan (``analysis_plan``) unrolls every
    loop and widens chunks to one trip so XLA's HLO cost analysis — which counts a
    ``while`` body exactly once — sees the true FLOP/byte/collective totals; analysis
    lowerings are never executed, so their absurd intermediate sizes don't matter.
    """

    attn_chunk: int = 1024      # flash key-chunk
    loss_chunk: int = 512       # CE vocab-matmul sequence chunk
    ssm_chunk: int = 128        # mamba associative-scan chunk
    remat: str = "full"         # none | full | dots
    unroll: Any = 1             # lax.scan unroll for the layer stack


def analysis_plan(seq_len: int, *, remat: str = "full") -> ExecPlan:
    big = max(seq_len, 1)
    return ExecPlan(attn_chunk=big, loss_chunk=big, ssm_chunk=big, remat=remat, unroll=True)


# ===================================================================== layer windows


def layer_windows(cfg: ArchConfig) -> jnp.ndarray:
    """Per-layer attention window (int32, 0 = global/full) — the gemma3 5:1 pattern,
    mixtral's uniform SWA, or all-zeros for full attention."""
    if cfg.attn_kind == "local_global" and cfg.local_global_ratio > 0:
        period = cfg.local_global_ratio + 1
        idx = jnp.arange(cfg.num_layers)
        return jnp.where(idx % period < cfg.local_global_ratio, cfg.window, 0).astype(jnp.int32)
    if cfg.attn_kind == "swa" and cfg.window > 0:
        return jnp.full((cfg.num_layers,), cfg.window, jnp.int32)
    return jnp.zeros((cfg.num_layers,), jnp.int32)


def cache_lengths(cfg: ArchConfig, seq_len: int) -> jnp.ndarray:
    """Per-layer KV cache length: SWA layers keep a rolling ``window`` buffer."""
    w = layer_windows(cfg)
    return jnp.where(w > 0, jnp.minimum(w, seq_len), seq_len)


# ===================================================================== init


def _init_attn(key, cfg: ArchConfig, dtype) -> dict:
    if cfg.mla:
        return attention.init_mla(
            key,
            cfg.d_model,
            cfg.num_heads,
            q_lora=cfg.q_lora_rank,
            kv_lora=cfg.kv_lora_rank,
            nope=cfg.qk_nope_dim,
            rope_d=cfg.qk_rope_dim,
            v_dim=cfg.v_head_dim,
            dtype=dtype,
        )
    return attention.init_gqa(
        key, cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim, dtype
    )


def _init_layer(key, cfg: ArchConfig, dtype) -> dict:
    """One decoder layer's params; vmapped over L keys to build the stacked tree."""
    ks = jax.random.split(key, 8)
    p: Dict[str, Any] = {"norm1": layers.init_rmsnorm(cfg.d_model, dtype)}
    if cfg.family == "ssm":
        p["mamba"] = ssm_lib.init_mamba(
            ks[0],
            cfg.d_model,
            d_inner=cfg.d_inner,
            state=cfg.ssm_state,
            d_conv=cfg.d_conv,
            dt_rank=cfg.resolved_dt_rank,
            dtype=dtype,
        )
        return p
    p["attn"] = _init_attn(ks[0], cfg, dtype)
    if cfg.hybrid:
        p["mamba"] = ssm_lib.init_mamba(
            ks[1],
            cfg.d_model,
            d_inner=cfg.d_inner,
            state=cfg.ssm_state,
            d_conv=cfg.d_conv,
            dt_rank=cfg.resolved_dt_rank,
            dtype=dtype,
        )
        p["fuse"] = {
            "norm_a": layers.init_rmsnorm(cfg.d_model, dtype),
            "norm_s": layers.init_rmsnorm(cfg.d_model, dtype),
            "beta_a": jnp.full((cfg.d_model,), 0.5, dtype),
            "beta_s": jnp.full((cfg.d_model,), 0.5, dtype),
        }
    if cfg.encdec:
        p["norm_x"] = layers.init_rmsnorm(cfg.d_model, dtype)
        p["xattn"] = attention.init_gqa(
            ks[2], cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim, dtype
        )
    p["norm2"] = layers.init_rmsnorm(cfg.d_model, dtype)
    if cfg.moe:
        p["moe"] = moe_lib.init_moe(ks[3], cfg.d_model, cfg.d_ff, cfg.num_experts, dtype)
    else:
        p["ffn"] = layers.init_swiglu(ks[3], cfg.d_model, cfg.d_ff, dtype)
    return p


def _init_enc_layer(key, cfg: ArchConfig, dtype) -> dict:
    ks = jax.random.split(key, 2)
    return {
        "norm1": layers.init_rmsnorm(cfg.d_model, dtype),
        "attn": attention.init_gqa(
            ks[0], cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim, dtype
        ),
        "norm2": layers.init_rmsnorm(cfg.d_model, dtype),
        "ffn": layers.init_swiglu(ks[1], cfg.d_model, cfg.d_ff, dtype),
    }


def init_params(cfg: ArchConfig, key: jax.Array) -> PyTree:
    """Full parameter tree. Layer leaves are stacked with a leading L axis."""
    dtype = _dtype(cfg)
    k_emb, k_layers, k_norm, k_un, k_enc, k_vit = jax.random.split(key, 6)
    params: Dict[str, Any] = {
        "embed": layers.init_embedding(k_emb, cfg.padded_vocab, cfg.d_model, dtype),
        "layers": jax.vmap(lambda k: _init_layer(k, cfg, dtype))(
            jax.random.split(k_layers, cfg.num_layers)
        ),
        "final_norm": layers.init_rmsnorm(cfg.d_model, dtype),
    }
    if cfg.tie_embeddings:
        pass  # unembed reuses embed.table
    else:
        params["unembed"] = layers.init_unembed(k_un, cfg.d_model, cfg.padded_vocab, dtype)
    if cfg.encdec:
        params["enc_layers"] = jax.vmap(lambda k: _init_enc_layer(k, cfg, dtype))(
            jax.random.split(k_enc, cfg.enc_layers)
        )
        params["enc_norm"] = layers.init_rmsnorm(cfg.d_model, dtype)
    if cfg.vlm:
        params["vit_proj"] = {
            "w": (jax.random.normal(k_vit, (cfg.vit_dim, cfg.d_model)) / math.sqrt(cfg.vit_dim)).astype(dtype)
        }
    return params


def param_shapes(cfg: ArchConfig) -> PyTree:
    """ShapeDtypeStruct tree without allocating — used by the dry-run / checkpoints."""
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


# ===================================================================== forward (train/prefill)


def _attn_block(lp, x, cfg: ArchConfig, window, *, plan: ExecPlan, rules=None):
    if cfg.mla:
        return attention.mla_forward(
            lp["attn"],
            x,
            heads=cfg.num_heads,
            q_lora=cfg.q_lora_rank,
            kv_lora=cfg.kv_lora_rank,
            nope=cfg.qk_nope_dim,
            rope_d=cfg.qk_rope_dim,
            v_dim=cfg.v_head_dim,
            rope_theta=cfg.rope_theta,
            chunk=plan.attn_chunk,
            rules=rules,
        )
    return attention.gqa_forward(
        lp["attn"],
        x,
        heads=cfg.num_heads,
        kv_heads=cfg.num_kv_heads,
        head_dim=cfg.resolved_head_dim,
        rope_theta=cfg.rope_theta,
        rope_fraction=cfg.rope_fraction,
        window=window,
        chunk=plan.attn_chunk,
        rules=rules,
    )


def _layer_fwd(lp, x, cfg: ArchConfig, window, *, rules, plan: ExecPlan, enc_out=None):
    """One decoder layer (training/prefill). Returns (x, aux_loss).

    Layer-boundary activations are *sequence-parallel*: (B, S, d) is sharded
    (dp, tensor, –) so the per-layer remat residual divides by the tensor width.
    """
    aux = jnp.zeros((), jnp.float32)
    x = constrain(x, rules, "dp", "sp", None)
    if cfg.family == "ssm":
        h = layers.rmsnorm(lp["norm1"], x, cfg.norm_eps)
        x = x + ssm_lib.mamba_forward(
            lp["mamba"], h, state=cfg.ssm_state, dt_rank=cfg.resolved_dt_rank, chunk=plan.ssm_chunk
        )
        return x, aux
    h = layers.rmsnorm(lp["norm1"], x, cfg.norm_eps)
    a = _attn_block(lp, h, cfg, window, plan=plan, rules=rules)
    if cfg.hybrid:
        s = ssm_lib.mamba_forward(
            lp["mamba"], h, state=cfg.ssm_state, dt_rank=cfg.resolved_dt_rank, chunk=plan.ssm_chunk
        )
        a = layers.rmsnorm(lp["fuse"]["norm_a"], a, cfg.norm_eps) * lp["fuse"]["beta_a"]
        a = a + layers.rmsnorm(lp["fuse"]["norm_s"], s, cfg.norm_eps) * lp["fuse"]["beta_s"]
    x = x + a
    if cfg.encdec:
        hx = layers.rmsnorm(lp["norm_x"], x, cfg.norm_eps)
        x = x + attention.gqa_forward(
            lp["xattn"],
            hx,
            heads=cfg.num_heads,
            kv_heads=cfg.num_kv_heads,
            head_dim=cfg.resolved_head_dim,
            rope_theta=cfg.rope_theta,
            causal=False,
            kv_source=enc_out,
            chunk=plan.attn_chunk,
            rules=rules,
        )
    h2 = layers.rmsnorm(lp["norm2"], x, cfg.norm_eps)
    if cfg.moe:
        # MoE dispatch sorts along the sequence axis — keep that axis LOCAL (un-SP
        # the block) or every argsort/gather crosses the model axis. One all-gather
        # in, one reduce back out beats per-expert collective thrash (§Perf iter on
        # grok-1: the baseline compiled to 2.6k all-to-alls per step).
        h2 = constrain(h2, rules, "dp", None, None)
        f, aux = moe_lib.moe_forward(
            lp["moe"],
            h2,
            num_experts=cfg.num_experts,
            top_k=cfg.top_k,
            capacity_factor=cfg.capacity_factor,
            rules=rules,
        )
        f = constrain(f, rules, "dp", "sp", None)
    else:
        f = layers.swiglu(lp["ffn"], h2)
    return x + f, aux


def encoder_forward(
    params, cfg: ArchConfig, frames: jax.Array, *, rules=None, plan: ExecPlan = ExecPlan()
):
    """Bidirectional encoder over precomputed frame embeddings (whisper stub)."""

    def body(x, lp):
        x = constrain(x, rules, "dp", None, None)
        h = layers.rmsnorm(lp["norm1"], x, cfg.norm_eps)
        x = x + attention.gqa_forward(
            lp["attn"],
            h,
            heads=cfg.num_heads,
            kv_heads=cfg.num_kv_heads,
            head_dim=cfg.resolved_head_dim,
            rope_theta=cfg.rope_theta,
            causal=False,
            chunk=plan.attn_chunk,
            rules=rules,
        )
        h2 = layers.rmsnorm(lp["norm2"], x, cfg.norm_eps)
        return x + layers.swiglu(lp["ffn"], h2), None

    x, _ = jax.lax.scan(jax.checkpoint(body), frames, params["enc_layers"], unroll=plan.unroll)
    return layers.rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def trunk(
    params,
    cfg: ArchConfig,
    x: jax.Array,
    *,
    rules: Optional[ShardingRules] = None,
    enc_out: Optional[jax.Array] = None,
    plan: ExecPlan = ExecPlan(),
) -> Tuple[jax.Array, jax.Array]:
    """Scan the stacked layers over x: (B, S, d). Returns (hidden, moe_aux_sum)."""
    windows = layer_windows(cfg)

    def body(carry, xs):
        x, aux_acc = carry
        lp, window = xs
        x, aux = _layer_fwd(lp, x, cfg, window, rules=rules, plan=plan, enc_out=enc_out)
        return (x, aux_acc + aux), None

    if plan.remat == "full":
        body = jax.checkpoint(body, prevent_cse=False)
    elif plan.remat == "dots":
        body = jax.checkpoint(
            body,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
            prevent_cse=False,
        )
    (x, aux), _ = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), (params["layers"], windows), unroll=plan.unroll
    )
    return layers.rmsnorm(params["final_norm"], x, cfg.norm_eps), aux


def embed_inputs(
    params, cfg: ArchConfig, batch: Dict[str, jax.Array], *, rules=None, plan: ExecPlan = ExecPlan()
):
    """Token (+frontend-stub) embedding. Returns (x, loss_mask, enc_out)."""
    x = layers.embed(params["embed"], batch["tokens"])
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones(batch["tokens"].shape, jnp.float32)
    enc_out = None
    if cfg.vlm and "patches" in batch:
        proj = jnp.einsum("bpv,vd->bpd", batch["patches"].astype(x.dtype), params["vit_proj"]["w"])
        P_img = proj.shape[1]
        x = jnp.concatenate([proj, x[:, P_img:]], axis=1)
        mask = jnp.concatenate([jnp.zeros((x.shape[0], P_img), jnp.float32), mask[:, P_img:]], axis=1)
    if cfg.encdec and "frames" in batch:
        enc_out = encoder_forward(params, cfg, batch["frames"].astype(x.dtype), rules=rules, plan=plan)
    return x, mask, enc_out


def _unembed_w(params, cfg: ArchConfig):
    if cfg.tie_embeddings:
        return params["embed"]["table"].T
    return params["unembed"]["w"]


def chunked_ce_loss(
    h: jax.Array,
    w: jax.Array,
    labels: jax.Array,
    mask: jax.Array,
    *,
    chunk: int = 512,
    rules: Optional[ShardingRules] = None,
) -> jax.Array:
    """Next-token CE without materializing (B, S, V) logits.

    Scans the sequence in chunks; each chunk's logits are produced, reduced to a
    scalar, and discarded (jax.checkpoint → recomputed in backward). The vocab axis
    of the matmul is tensor-sharded; the logsumexp reduces across it (one psum per
    chunk, inserted by GSPMD).
    """
    B, S, d = h.shape
    n_chunks = -(-S // chunk)
    S_pad = n_chunks * chunk
    if S_pad != S:
        h = jnp.pad(h, ((0, 0), (0, S_pad - S), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, S_pad - S)))
        mask = jnp.pad(mask, ((0, 0), (0, S_pad - S)))
    hc = h.reshape(B, n_chunks, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n_chunks, chunk).transpose(1, 0, 2)
    mc = mask.reshape(B, n_chunks, chunk).transpose(1, 0, 2)

    def body(acc, xs):
        hj, lj, mj = xs
        logits = jnp.einsum("bsd,dv->bsv", hj, w).astype(jnp.float32)
        logits = constrain(logits, rules, "dp", None, "tensor")
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lj[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * mj
        return (acc[0] + jnp.sum(nll), acc[1] + jnp.sum(mj)), None

    body = jax.checkpoint(body, prevent_cse=False)
    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)), (hc, lc, mc))
    return tot / jnp.maximum(cnt, 1.0)


def lm_loss(
    params,
    cfg: ArchConfig,
    batch: Dict[str, jax.Array],
    *,
    rules: Optional[ShardingRules] = None,
    plan: ExecPlan = ExecPlan(),
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Mean next-token CE (+ MoE aux). The single entry point for training."""
    x, mask, enc_out = embed_inputs(params, cfg, batch, rules=rules, plan=plan)
    h, aux = trunk(params, cfg, x, rules=rules, enc_out=enc_out, plan=plan)
    # shift: predict token t+1 from position t
    labels = batch["labels"]
    ce = chunked_ce_loss(
        h[:, :-1], _unembed_w(params, cfg), labels[:, 1:], mask[:, 1:], chunk=plan.loss_chunk, rules=rules
    )
    loss = ce + cfg.router_aux_coef * aux
    return loss, {"ce": ce, "moe_aux": aux}


def forward_logits(
    params,
    cfg: ArchConfig,
    batch: Dict[str, jax.Array],
    *,
    rules=None,
    plan: ExecPlan = ExecPlan(remat="none"),
) -> jax.Array:
    """Full (B, S, V_pad) logits — small models / tests only (no chunking)."""
    x, _, enc_out = embed_inputs(params, cfg, batch, rules=rules, plan=plan)
    h, _ = trunk(params, cfg, x, rules=rules, enc_out=enc_out, plan=plan)
    return jnp.einsum("bsd,dv->bsv", h, _unembed_w(params, cfg)).astype(jnp.float32)


# ===================================================================== KV cache


def init_cache(cfg: ArchConfig, batch: int, seq_len: int, *, dtype=None) -> PyTree:
    """Decode cache for ``seq_len`` context. Stacked (L, ...) leaves.

    SWA layers keep a rolling window buffer; for local_global (gemma3) the cache is
    split into a 'local' stack (ring of ``window``) and a 'global' stack (full
    ``seq_len``) so the 5:1 pattern doesn't pay full-context memory on local layers.
    """
    dtype = dtype or _dtype(cfg)
    L, hd, KV = cfg.num_layers, cfg.resolved_head_dim, cfg.num_kv_heads
    cache: Dict[str, Any] = {}

    def kv(nl, s):
        return {
            "k": jnp.zeros((nl, batch, s, KV, hd), dtype),
            "v": jnp.zeros((nl, batch, s, KV, hd), dtype),
        }

    if cfg.family == "ssm":
        cache["conv"] = jnp.zeros((L, batch, cfg.d_conv - 1, cfg.d_inner), dtype)
        cache["ssm"] = jnp.zeros((L, batch, cfg.d_inner, cfg.ssm_state), jnp.float32)
        return cache
    if cfg.mla:
        cache["ckv"] = jnp.zeros((L, batch, seq_len, cfg.kv_lora_rank), dtype)
        cache["krope"] = jnp.zeros((L, batch, seq_len, cfg.qk_rope_dim), dtype)
        return cache
    if cfg.attn_kind == "local_global" and cfg.local_global_ratio > 0:
        period = cfg.local_global_ratio + 1
        n_groups = L // period
        cache["local"] = kv(n_groups * cfg.local_global_ratio, min(cfg.window, seq_len))
        cache["global"] = kv(n_groups, seq_len)
    else:
        s = min(cfg.window, seq_len) if (cfg.attn_kind == "swa" and cfg.window > 0) else seq_len
        cache.update(kv(L, s))
    if cfg.hybrid:
        cache["conv"] = jnp.zeros((L, batch, cfg.d_conv - 1, cfg.d_inner), dtype)
        cache["ssm"] = jnp.zeros((L, batch, cfg.d_inner, cfg.ssm_state), jnp.float32)
    if cfg.encdec:
        cache["xk"] = jnp.zeros((L, batch, cfg.enc_seq, KV, hd), dtype)
        cache["xv"] = jnp.zeros((L, batch, cfg.enc_seq, KV, hd), dtype)
    return cache


def cache_shapes(cfg: ArchConfig, batch: int, seq_len: int) -> PyTree:
    return jax.eval_shape(lambda: init_cache(cfg, batch, seq_len))


# ===================================================================== decode


def _ring_update_and_scores_mask(pos: jax.Array, s_cache: int):
    """Slot + absolute positions for a ring buffer of size s_cache at step pos."""
    slot = jnp.mod(pos, s_cache)
    idx = jnp.arange(s_cache)
    ages = jnp.mod(pos - idx, s_cache)
    k_pos = pos - ages
    valid = k_pos >= 0
    return slot, valid


def _gqa_ring_decode(lp, x, ck, cv, pos, cfg: ArchConfig):
    """GQA decode against a (possibly rolling) cache. ck/cv: (B, Sc, KV, hd)."""
    B = x.shape[0]
    Sc = ck.shape[1]
    hd, H, KV = cfg.resolved_head_dim, cfg.num_heads, cfg.num_kv_heads
    q = jnp.einsum("bsd,dh->bsh", x, lp["wq"]).reshape(B, 1, H, hd)
    k = jnp.einsum("bsd,dh->bsh", x, lp["wk"]).reshape(B, 1, KV, hd)
    v = jnp.einsum("bsd,dh->bsh", x, lp["wv"]).reshape(B, 1, KV, hd)
    rot = int(hd * cfg.rope_fraction) & ~1
    cos, sin = layers.rope_angles(pos[None], rot, cfg.rope_theta)
    q = layers.apply_rope(q, cos[None], sin[None], cfg.rope_fraction)
    k = layers.apply_rope(k, cos[None], sin[None], cfg.rope_fraction)

    slot, valid = _ring_update_and_scores_mask(pos, Sc)
    ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, slot, 0, 0))

    G = H // KV
    qf = (q.astype(jnp.float32) / math.sqrt(hd)).reshape(B, KV, G, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qf, ck.astype(jnp.float32))
    s = jnp.where(valid[None, None, None, :], s, attention.NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", p, cv.astype(jnp.float32)).reshape(B, 1, H * hd)
    out = jnp.einsum("bsh,hd->bsd", out.astype(x.dtype), lp["wo"])
    return out, ck, cv


def _decode_layer(lp, x, lc, pos, cfg: ArchConfig, *, enc_cached=False):
    """One layer's decode. lc = this layer's cache slice dict. Returns (x, lc)."""
    h = layers.rmsnorm(lp["norm1"], x, cfg.norm_eps)
    if cfg.family == "ssm":
        out, conv, ssm_state = ssm_lib.mamba_decode(
            lp["mamba"], h, lc["conv"], lc["ssm"], state=cfg.ssm_state, dt_rank=cfg.resolved_dt_rank
        )
        return x + out, {"conv": conv, "ssm": ssm_state}
    if cfg.mla:
        out, ckv, krope = attention.mla_decode(
            lp["attn"],
            h,
            lc["ckv"],
            lc["krope"],
            pos,
            heads=cfg.num_heads,
            kv_lora=cfg.kv_lora_rank,
            nope=cfg.qk_nope_dim,
            rope_d=cfg.qk_rope_dim,
            v_dim=cfg.v_head_dim,
            rope_theta=cfg.rope_theta,
        )
        x = x + out
        lc = {"ckv": ckv, "krope": krope}
    else:
        a, ck, cv = _gqa_ring_decode(lp["attn"], h, lc["k"], lc["v"], pos, cfg)
        new_lc = {"k": ck, "v": cv}
        if cfg.hybrid:
            s_out, conv, ssm_state = ssm_lib.mamba_decode(
                lp["mamba"], h, lc["conv"], lc["ssm"], state=cfg.ssm_state, dt_rank=cfg.resolved_dt_rank
            )
            a = layers.rmsnorm(lp["fuse"]["norm_a"], a, cfg.norm_eps) * lp["fuse"]["beta_a"]
            a = a + layers.rmsnorm(lp["fuse"]["norm_s"], s_out, cfg.norm_eps) * lp["fuse"]["beta_s"]
            new_lc.update({"conv": conv, "ssm": ssm_state})
        x = x + a
        if cfg.encdec:
            hx = layers.rmsnorm(lp["norm_x"], x, cfg.norm_eps)
            x = x + attention.cross_decode(
                lp["xattn"],
                hx,
                lc["xk"],
                lc["xv"],
                heads=cfg.num_heads,
                kv_heads=cfg.num_kv_heads,
                head_dim=cfg.resolved_head_dim,
            )
            new_lc.update({"xk": lc["xk"], "xv": lc["xv"]})
        lc = new_lc
    h2 = layers.rmsnorm(lp["norm2"], x, cfg.norm_eps)
    if cfg.moe:
        B = x.shape[0]
        f, _ = moe_lib.moe_forward(
            lp["moe"],
            h2.reshape(1, B, cfg.d_model),
            num_experts=cfg.num_experts,
            top_k=cfg.top_k,
            capacity_factor=cfg.capacity_factor,
        )
        f = f.reshape(B, 1, cfg.d_model)
    else:
        f = layers.swiglu(lp["ffn"], h2)
    return x + f, lc


def decode_step(
    params,
    cfg: ArchConfig,
    tokens: jax.Array,
    cache: PyTree,
    pos: jax.Array,
    *,
    rules: Optional[ShardingRules] = None,
    x_embed: Optional[jax.Array] = None,
    plan: ExecPlan = ExecPlan(),
) -> Tuple[jax.Array, PyTree]:
    """One-token decode. tokens: (B,) int32; pos: () int32 (current position).

    ``x_embed`` (B, d): pre-embedded input overriding the token lookup — used by the
    token-by-token prefill of multimodal prompts (patch embeddings at image slots).
    Returns (logits (B, V_pad), new cache).
    """
    x = layers.embed(params["embed"], tokens[:, None]) if x_embed is None else x_embed[:, None, :]
    x = constrain(x, rules, "dp", None, None)

    if cfg.attn_kind == "local_global" and cfg.local_global_ratio > 0:
        x, cache = _decode_local_global(params, cfg, x, cache, pos, unroll=plan.unroll)
    else:
        keys = [k for k in ("k", "v", "ckv", "krope", "conv", "ssm", "xk", "xv") if k in cache]

        def body(x, xs):
            lp, lc = xs
            x, lc = _decode_layer(lp, x, lc, pos, cfg)
            return x, lc

        x, new_stacked = jax.lax.scan(
            body, x, (params["layers"], {k: cache[k] for k in keys}), unroll=plan.unroll
        )
        cache = dict(cache, **new_stacked)

    h = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", h, _unembed_w(params, cfg))[:, 0].astype(jnp.float32)
    logits = constrain(logits, rules, "dp", "tensor")
    return logits, cache


def _decode_local_global(params, cfg: ArchConfig, x, cache, pos, *, unroll=1):
    """gemma3 5:1 decode: scan over groups; each group = R local layers + 1 global.

    The local stack's ring caches and the global stack's full caches have different
    sequence lengths, so they live in separate stacked pytrees.
    """
    R = cfg.local_global_ratio
    period = R + 1
    G = cfg.num_layers // period

    def regroup(leaf):  # (L, ...) -> (G, period, ...)
        return leaf.reshape((G, period) + leaf.shape[1:])

    gp = jax.tree_util.tree_map(regroup, params["layers"])
    lp_local = jax.tree_util.tree_map(lambda l: l[:, :R], gp)
    lp_global = jax.tree_util.tree_map(lambda l: l[:, R], gp)

    def lc_regroup(leaf):  # (G*R, ...) -> (G, R, ...)
        return leaf.reshape((G, R) + leaf.shape[1:])

    local_c = jax.tree_util.tree_map(lc_regroup, cache["local"])

    def body(x, xs):
        lpl, lpg, lcl, lcg = xs
        new_lcl = []
        for r in range(R):  # static unroll: R = 5
            lp_r = jax.tree_util.tree_map(lambda l: l[r], lpl)
            lc_r = jax.tree_util.tree_map(lambda l: l[r], lcl)
            x_new, lc_r = _decode_layer(lp_r, x, lc_r, pos, cfg)
            x = x_new
            new_lcl.append(lc_r)
        lcl = jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *new_lcl)
        x, lcg = _decode_layer(lpg, x, lcg, pos, cfg)
        return x, (lcl, lcg)

    x, (new_local, new_global) = jax.lax.scan(
        body, x, (lp_local, lp_global, local_c, cache["global"]), unroll=unroll
    )
    new_local = jax.tree_util.tree_map(lambda l: l.reshape((G * R,) + l.shape[2:]), new_local)
    return x, dict(cache, local=new_local, **{"global": new_global})


# ===================================================================== prefill


def _layer_prefill(lp, x, cfg: ArchConfig, window, *, rules, plan: ExecPlan, enc_out=None):
    """_layer_fwd twin that also returns this layer's decode-cache piece."""
    piece: Dict[str, jax.Array] = {}
    x = constrain(x, rules, "dp", "sp", None)
    if cfg.family == "ssm":
        h = layers.rmsnorm(lp["norm1"], x, cfg.norm_eps)
        out, (tail, hT) = ssm_lib.mamba_forward(
            lp["mamba"], h, state=cfg.ssm_state, dt_rank=cfg.resolved_dt_rank,
            chunk=plan.ssm_chunk, return_state=True
        )
        return x + out, {"conv": tail, "ssm": hT}
    h = layers.rmsnorm(lp["norm1"], x, cfg.norm_eps)
    if cfg.mla:
        a, (ckv, krope) = attention.mla_forward(
            lp["attn"],
            h,
            heads=cfg.num_heads,
            q_lora=cfg.q_lora_rank,
            kv_lora=cfg.kv_lora_rank,
            nope=cfg.qk_nope_dim,
            rope_d=cfg.qk_rope_dim,
            v_dim=cfg.v_head_dim,
            rope_theta=cfg.rope_theta,
            chunk=plan.attn_chunk,
            rules=rules,
            return_kv=True,
        )
        piece.update({"ckv": ckv, "krope": krope})
    else:
        a, (k, v) = attention.gqa_forward(
            lp["attn"],
            h,
            heads=cfg.num_heads,
            kv_heads=cfg.num_kv_heads,
            head_dim=cfg.resolved_head_dim,
            rope_theta=cfg.rope_theta,
            rope_fraction=cfg.rope_fraction,
            window=window,
            chunk=plan.attn_chunk,
            rules=rules,
            return_kv=True,
        )
        piece.update({"k": k, "v": v})
    if cfg.hybrid:
        s, (tail, hT) = ssm_lib.mamba_forward(
            lp["mamba"], h, state=cfg.ssm_state, dt_rank=cfg.resolved_dt_rank,
            chunk=plan.ssm_chunk, return_state=True
        )
        a = layers.rmsnorm(lp["fuse"]["norm_a"], a, cfg.norm_eps) * lp["fuse"]["beta_a"]
        a = a + layers.rmsnorm(lp["fuse"]["norm_s"], s, cfg.norm_eps) * lp["fuse"]["beta_s"]
        piece.update({"conv": tail, "ssm": hT})
    x = x + a
    if cfg.encdec:
        hx = layers.rmsnorm(lp["norm_x"], x, cfg.norm_eps)
        xa, (xk, xv) = attention.gqa_forward(
            lp["xattn"],
            hx,
            heads=cfg.num_heads,
            kv_heads=cfg.num_kv_heads,
            head_dim=cfg.resolved_head_dim,
            rope_theta=cfg.rope_theta,
            causal=False,
            kv_source=enc_out,
            chunk=plan.attn_chunk,
            rules=rules,
            return_kv=True,
        )
        x = x + xa
        piece.update({"xk": xk, "xv": xv})
    h2 = layers.rmsnorm(lp["norm2"], x, cfg.norm_eps)
    if cfg.moe:
        h2 = constrain(h2, rules, "dp", None, None)  # see _layer_fwd: SP-local MoE
        f, _ = moe_lib.moe_forward(
            lp["moe"], h2, num_experts=cfg.num_experts, top_k=cfg.top_k,
            capacity_factor=cfg.capacity_factor, rules=rules,
        )
        f = constrain(f, rules, "dp", "sp", None)
    else:
        f = layers.swiglu(lp["ffn"], h2)
    return x + f, piece


def _ring_place(k_all: jax.Array, s_cache: int) -> jax.Array:
    """Scatter the last min(s_cache, S) positions of (L, B, S, ...) into a ring of
    ``s_cache`` slots at indices p % s_cache (static — S and s_cache are concrete)."""
    import numpy as np

    L, B, S = k_all.shape[:3]
    out = jnp.zeros(k_all.shape[:2] + (s_cache,) + k_all.shape[3:], k_all.dtype)
    take = min(s_cache, S)
    positions = np.arange(S - take, S)
    slots = positions % s_cache
    return out.at[:, :, slots].set(k_all[:, :, S - take:])


def batched_prefill(
    params,
    cfg: ArchConfig,
    batch: Dict[str, jax.Array],
    *,
    cache_len: Optional[int] = None,
    rules: Optional[ShardingRules] = None,
    plan: ExecPlan = ExecPlan(),
) -> Tuple[jax.Array, PyTree]:
    """Flash prefill: one batched pass over the prompt.

    Returns (last-token logits (B, V_pad), a decode cache positioned at pos = S).
    This is what the ``prefill_32k`` dry-run cells lower — the production
    prompt-processing step, O(S·window) attention for SWA layers, O(S²/2) global.
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    cache_len = cache_len or S
    x, _, enc_out = embed_inputs(params, cfg, batch, rules=rules, plan=plan)
    windows = layer_windows(cfg)

    def body(x, xs):
        lp, window = xs
        x, piece = _layer_prefill(lp, x, cfg, window, rules=rules, plan=plan, enc_out=enc_out)
        return x, piece

    x, pieces = jax.lax.scan(
        jax.checkpoint(body, prevent_cse=False), x, (params["layers"], windows), unroll=plan.unroll
    )
    h = layers.rmsnorm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", h, _unembed_w(params, cfg))[:, 0].astype(jnp.float32)
    logits = constrain(logits, rules, "dp", "tensor")

    cache: Dict[str, Any] = {}
    if cfg.family == "ssm":
        return logits, {"conv": pieces["conv"], "ssm": pieces["ssm"]}
    if cfg.mla:
        for name in ("ckv", "krope"):
            full = jnp.zeros(
                pieces[name].shape[:2] + (cache_len,) + pieces[name].shape[3:], pieces[name].dtype
            )
            cache[name] = jax.lax.dynamic_update_slice(
                full, pieces[name], (0, 0, 0) + (0,) * (full.ndim - 3)
            )
        return logits, cache
    if cfg.attn_kind == "local_global" and cfg.local_global_ratio > 0:
        import numpy as np

        R, period = cfg.local_global_ratio, cfg.local_global_ratio + 1
        is_local = np.arange(cfg.num_layers) % period < R
        local_idx = np.arange(cfg.num_layers)[is_local]
        global_idx = np.arange(cfg.num_layers)[~is_local]
        cache["local"] = {
            n: _ring_place(pieces[n][local_idx], min(cfg.window, cache_len)) for n in ("k", "v")
        }
        cache["global"] = {
            n: _pad_seq(pieces[n][global_idx], cache_len) for n in ("k", "v")
        }
    else:
        if cfg.attn_kind == "swa" and cfg.window > 0:
            sc = min(cfg.window, cache_len)
            cache["k"] = _ring_place(pieces["k"], sc)
            cache["v"] = _ring_place(pieces["v"], sc)
        else:
            cache["k"] = _pad_seq(pieces["k"], cache_len)
            cache["v"] = _pad_seq(pieces["v"], cache_len)
    if cfg.hybrid:
        cache["conv"] = pieces["conv"]
        cache["ssm"] = pieces["ssm"]
    if cfg.encdec:
        cache["xk"] = pieces["xk"]
        cache["xv"] = pieces["xv"]
    return logits, cache


def _pad_seq(k_all: jax.Array, cache_len: int) -> jax.Array:
    """(L, B, S, ...) -> (L, B, cache_len, ...) zero-extended on the sequence axis."""
    L, B, S = k_all.shape[:3]
    if S == cache_len:
        return k_all
    if S > cache_len:
        return k_all[:, :, S - cache_len:]
    pad = [(0, 0), (0, 0), (0, cache_len - S)] + [(0, 0)] * (k_all.ndim - 3)
    return jnp.pad(k_all, pad)


def prefill(
    params,
    cfg: ArchConfig,
    batch: Dict[str, jax.Array],
    cache: PyTree,
    *,
    rules: Optional[ShardingRules] = None,
    chunk: int = 1024,
) -> Tuple[jax.Array, PyTree]:
    """Fill the cache from a prompt by stepping decode over positions.

    Token-by-token prefill (a lax.fori_loop over decode_step) — O(S) steps but exactly
    one code path for cache semantics (ring buffers, SSM states, MLA latents). The
    batched flash prefill is used for logits-only paths; serving throughput on TPU
    would fuse the two (chunked prefill), which we leave as the documented fast path
    for the prefill_32k dry-run cell (it lowers ``lm_loss``-style trunk instead).
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    if cfg.encdec and "frames" in batch:
        enc_out = encoder_forward(params, cfg, batch["frames"].astype(_dtype(cfg)), rules=rules)
        xk = jnp.einsum(
            "bsd,ldh->lbsh", enc_out, params["layers"]["xattn"]["wk"]
        ).reshape(cfg.num_layers, B, cfg.enc_seq, cfg.num_kv_heads, cfg.resolved_head_dim)
        xv = jnp.einsum(
            "bsd,ldh->lbsh", enc_out, params["layers"]["xattn"]["wv"]
        ).reshape(cfg.num_layers, B, cfg.enc_seq, cfg.num_kv_heads, cfg.resolved_head_dim)
        cache = dict(cache, xk=xk.astype(cache["xk"].dtype), xv=xv.astype(cache["xv"].dtype))

    # Pre-merge frontend-stub embeddings (VLM patches) so position i's input is
    # identical to the batched path's.
    x_all, _, _ = embed_inputs(params, cfg, {k: v for k, v in batch.items() if k != "frames"}, rules=rules)

    def step(i, carry):
        logits, cache = carry
        tok = jax.lax.dynamic_slice(tokens, (0, i), (B, 1))[:, 0]
        xe = jax.lax.dynamic_slice(x_all, (0, i, 0), (B, 1, x_all.shape[-1]))[:, 0]
        logits, cache = decode_step(params, cfg, tok, cache, i, rules=rules, x_embed=xe)
        return logits, cache

    logits0 = jnp.zeros((B, cfg.padded_vocab), jnp.float32)
    logits, cache = jax.lax.fori_loop(0, S, step, (logits0, cache))
    return logits, cache
