"""Attention: GQA / MLA / sliding-window, chunked-flash for long context, and
single-token decode against a KV cache.

Memory strategy: training/prefill attention is *chunked flash* — an online-softmax
scan over key blocks — so peak memory is O(S·chunk) instead of O(S²); at 32k prefill
a dense score tensor would be ~8 GiB/device and is a non-starter. Decode attention is
one-token-vs-cache einsums; when the cache's sequence axis is sharded (long_500k),
the softmax reduction spans shards and GSPMD inserts the cross-shard all-reduce of the
running (max, sum) pair.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import layers

NEG_INF = -1e30


def _dense(key, shape, fan_in, dtype):
    return (jax.random.normal(key, shape) * (1.0 / math.sqrt(fan_in))).astype(dtype)


def _window_mask(q_pos, k_pos, window):
    """(Sq, Sk) bool window mask; ``window`` may be a static int or a traced scalar
    (per-layer meta inside a lax.scan — gemma3's 5:1 local:global stack). window<=0
    means 'no window' (full attention)."""
    if isinstance(window, int):
        if window <= 0:
            return None
        return q_pos[:, None] - k_pos[None, :] < window
    return (q_pos[:, None] - k_pos[None, :] < window) | (window <= 0)


# ------------------------------------------------------------------ GQA params


def init_gqa(key, d: int, heads: int, kv_heads: int, head_dim: int, dtype) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": _dense(kq, (d, heads * head_dim), d, dtype),
        "wk": _dense(kk, (d, kv_heads * head_dim), d, dtype),
        "wv": _dense(kv, (d, kv_heads * head_dim), d, dtype),
        "wo": _dense(ko, (heads * head_dim, d), heads * head_dim, dtype),
    }


# ------------------------------------------------------------------ flash core


def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    chunk: int = 1024,
    q_offset: int = 0,
    rules=None,
) -> jax.Array:
    """Online-softmax attention. q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd).

    GQA is handled by reshaping H into (KV, H//KV) groups. ``window > 0`` restricts
    each query to the last ``window`` keys (sliding-window attention). ``q_offset``
    is the absolute position of q[0] relative to k[0] (for cross-chunk prefill).

    Sequence-parallel: queries (and therefore scores/accumulators — the O(S·chunk)
    term) are sharded over the tensor axis on Sq; K/V chunks are replicated. This
    works for *any* head count (minicpm3's 40 heads don't divide a 16-way mesh, so
    head-sharding the f32 score tile is not an option there).
    """
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    hd_v = v.shape[3]  # MLA: value head dim differs from the (nope+rope) qk dim
    G = H // KV
    scale = 1.0 / math.sqrt(hd)

    # Constrain BEFORE the f32 upcast: the TP→SP transition (an all-to-all in the
    # compiled HLO) then moves bf16, not f32 — half the wire bytes (§Perf iter 3).
    q = constrain(q.reshape(B, Sq, KV, G, hd), rules, "dp", "sp", None, None, None)
    qf = (q * scale).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    n_chunks = -(-Sk // chunk)
    Sk_pad = n_chunks * chunk
    if Sk_pad != Sk:
        pad = [(0, 0), (0, Sk_pad - Sk), (0, 0), (0, 0)]
        kf = jnp.pad(kf, pad)
        vf = jnp.pad(vf, pad)
    kc = kf.reshape(B, n_chunks, chunk, KV, hd).transpose(1, 0, 2, 3, 4)
    vc = vf.reshape(B, n_chunks, chunk, KV, hd_v).transpose(1, 0, 2, 3, 4)

    q_pos = q_offset + jnp.arange(Sq)

    def step(carry, xs):
        m_prev, l_prev, acc = carry
        kj, vj, j = xs
        k_pos = j * chunk + jnp.arange(chunk)
        s = jnp.einsum("bqkgh,bckh->bqkgc", qf, kj)  # (B, Sq, KV, G, chunk)
        mask = jnp.ones((Sq, chunk), bool)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        wm = _window_mask(q_pos, k_pos, window)
        if wm is not None:
            mask &= wm
        mask &= (k_pos < Sk)[None, :]
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[..., None])
        l_corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * l_corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bqkgc,bckh->bqkgh", p, vj)
        acc = acc * l_corr[..., None] + pv
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, Sq, KV, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, KV, G), jnp.float32)
    acc0 = jnp.zeros((B, Sq, KV, G, hd_v), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, acc0), (kc, vc, jnp.arange(n_chunks))
    )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, Sq, H, hd_v).astype(q.dtype)


# ------------------------------------------------------------------ GQA forward


def gqa_forward(
    params: dict,
    x: jax.Array,
    *,
    heads: int,
    kv_heads: int,
    head_dim: int,
    rope_theta: float,
    rope_fraction: float = 1.0,
    causal: bool = True,
    window: int = 0,
    chunk: int = 1024,
    kv_source: Optional[jax.Array] = None,
    return_kv: bool = False,
    rules=None,
):
    """Self (or cross, via kv_source) attention over x: (B, S, d).

    return_kv=True additionally returns the post-RoPE (k, v) — exactly what a decode
    cache stores — for the batched-prefill path."""
    B, S, _ = x.shape
    src = x if kv_source is None else kv_source
    Sk = src.shape[1]
    q = jnp.einsum("bsd,dh->bsh", x, params["wq"]).reshape(B, S, heads, head_dim)
    k = jnp.einsum("bsd,dh->bsh", src, params["wk"]).reshape(B, Sk, kv_heads, head_dim)
    v = jnp.einsum("bsd,dh->bsh", src, params["wv"]).reshape(B, Sk, kv_heads, head_dim)
    if causal and kv_source is None:
        cos_q, sin_q = layers.rope_angles(jnp.arange(S), int(head_dim * rope_fraction) & ~1, rope_theta)
        q = layers.apply_rope(q, cos_q[None], sin_q[None], rope_fraction)
        k = layers.apply_rope(k, cos_q[None], sin_q[None], rope_fraction)
    out = chunked_attention(
        q, k, v, causal=causal and kv_source is None, window=window, chunk=chunk, rules=rules
    )
    out = jnp.einsum("bsh,hd->bsd", out.reshape(B, S, heads * head_dim), params["wo"])
    if return_kv:
        return out, (k, v)
    return out


def gqa_decode(
    params: dict,
    x: jax.Array,
    cache_k: jax.Array,
    cache_v: jax.Array,
    pos: jax.Array,
    *,
    heads: int,
    kv_heads: int,
    head_dim: int,
    rope_theta: float,
    rope_fraction: float = 1.0,
    window: int = 0,
):
    """One-token decode. x: (B, 1, d); cache_k/v: (B, S, KV, hd); pos: () current
    position. Returns (out (B,1,d), new_cache_k, new_cache_v)."""
    B = x.shape[0]
    S = cache_k.shape[1]
    q = jnp.einsum("bsd,dh->bsh", x, params["wq"]).reshape(B, 1, heads, head_dim)
    k = jnp.einsum("bsd,dh->bsh", x, params["wk"]).reshape(B, 1, kv_heads, head_dim)
    v = jnp.einsum("bsd,dh->bsh", x, params["wv"]).reshape(B, 1, kv_heads, head_dim)
    rot = int(head_dim * rope_fraction) & ~1
    cos, sin = layers.rope_angles(pos[None], rot, rope_theta)
    q = layers.apply_rope(q, cos[None], sin[None], rope_fraction)
    k = layers.apply_rope(k, cos[None], sin[None], rope_fraction)

    cache_k = jax.lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype), (0, pos, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype), (0, pos, 0, 0))

    G = heads // kv_heads
    qf = (q.astype(jnp.float32) / math.sqrt(head_dim)).reshape(B, kv_heads, G, head_dim)
    kf = cache_k.astype(jnp.float32)
    vf = cache_v.astype(jnp.float32)
    s = jnp.einsum("bkgh,bskh->bkgs", qf, kf)  # (B, KV, G, S)
    k_pos = jnp.arange(S)
    valid = k_pos <= pos
    if isinstance(window, int):
        if window > 0:
            valid &= k_pos > pos - window
    else:
        valid &= (k_pos > pos - window) | (window <= 0)
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", p, vf).reshape(B, 1, heads * head_dim)
    out = jnp.einsum("bsh,hd->bsd", out.astype(x.dtype), params["wo"])
    return out, cache_k, cache_v


# ------------------------------------------------------------------ MLA


def init_mla(
    key,
    d: int,
    heads: int,
    *,
    q_lora: int,
    kv_lora: int,
    nope: int,
    rope_d: int,
    v_dim: int,
    dtype,
) -> dict:
    ks = jax.random.split(key, 6)
    return {
        "w_dq": _dense(ks[0], (d, q_lora), d, dtype),
        "w_uq": _dense(ks[1], (q_lora, heads * (nope + rope_d)), q_lora, dtype),
        "w_dkv": _dense(ks[2], (d, kv_lora + rope_d), d, dtype),
        "w_ukv": _dense(ks[3], (kv_lora, heads * (nope + v_dim)), kv_lora, dtype),
        "wo": _dense(ks[4], (heads * v_dim, d), heads * v_dim, dtype),
    }


def mla_forward(
    params: dict,
    x: jax.Array,
    *,
    heads: int,
    q_lora: int,
    kv_lora: int,
    nope: int,
    rope_d: int,
    v_dim: int,
    rope_theta: float,
    chunk: int = 1024,
    return_kv: bool = False,
    rules=None,
):
    """Training/prefill MLA: expand the latent to per-head K/V and run flash.

    return_kv=True additionally returns (c_kv, k_rope) — the latent decode cache."""
    B, S, _ = x.shape
    cq = jnp.einsum("bsd,dr->bsr", x, params["w_dq"])
    q = jnp.einsum("bsr,rh->bsh", cq, params["w_uq"]).reshape(B, S, heads, nope + rope_d)
    ckv_full = jnp.einsum("bsd,dr->bsr", x, params["w_dkv"])
    ckv, k_rope = ckv_full[..., :kv_lora], ckv_full[..., kv_lora:]
    kv = jnp.einsum("bsr,rh->bsh", ckv, params["w_ukv"]).reshape(B, S, heads, nope + v_dim)
    k_nope, v = kv[..., :nope], kv[..., nope:]

    cos, sin = layers.rope_angles(jnp.arange(S), rope_d, rope_theta)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = layers.apply_rope(q_rope, cos[None], sin[None])
    k_rope1 = layers.apply_rope(k_rope[:, :, None, :], cos[None], sin[None])  # (B,S,1,rope_d)
    k_rope = jnp.broadcast_to(k_rope1, (B, S, heads, rope_d))

    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate([k_nope, k_rope], axis=-1)
    out = chunked_attention(q_full, k_full, v, causal=True, chunk=chunk, rules=rules)
    out = jnp.einsum("bsh,hd->bsd", out.reshape(B, S, heads * v_dim), params["wo"])
    if return_kv:
        return out, (ckv, k_rope1[:, :, 0, :])
    return out


def mla_decode(
    params: dict,
    x: jax.Array,
    cache_ckv: jax.Array,
    cache_krope: jax.Array,
    pos: jax.Array,
    *,
    heads: int,
    kv_lora: int,
    nope: int,
    rope_d: int,
    v_dim: int,
    rope_theta: float,
):
    """Absorbed-matrix MLA decode (the MLA serving trick, TPU-native):

    Cache only the latent (c_kv, k_rope) — (kv_lora + rope_d) per position instead of
    heads·(nope+v). Scores fold W_ukv into the query:  s = (q_nopeᵀ·W_uk)·c_kv, and the
    value path stays latent until the final per-head expansion.
    """
    B = x.shape[0]
    S = cache_ckv.shape[1]
    w_uk = params["w_ukv"].reshape(kv_lora, heads, nope + v_dim)[:, :, :nope]  # (r, H, nope)
    w_uv = params["w_ukv"].reshape(kv_lora, heads, nope + v_dim)[:, :, nope:]  # (r, H, v)

    cq = jnp.einsum("bsd,dr->bsr", x, params["w_dq"])
    q = jnp.einsum("bsr,rh->bsh", cq, params["w_uq"]).reshape(B, heads, nope + rope_d)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    cos, sin = layers.rope_angles(pos[None], rope_d, rope_theta)
    q_rope = layers.apply_rope(q_rope[:, None], cos[None], sin[None])[:, 0]

    ckv_full = jnp.einsum("bsd,dr->bsr", x, params["w_dkv"])[:, 0]
    ckv_new, krope_new = ckv_full[..., :kv_lora], ckv_full[..., kv_lora:]
    krope_new = layers.apply_rope(krope_new[:, None, None, :], cos[None], sin[None])[:, 0, 0]

    cache_ckv = jax.lax.dynamic_update_slice(cache_ckv, ckv_new[:, None].astype(cache_ckv.dtype), (0, pos, 0))
    cache_krope = jax.lax.dynamic_update_slice(
        cache_krope, krope_new[:, None].astype(cache_krope.dtype), (0, pos, 0)
    )

    # absorbed scores: (B, H, r) @ (B, S, r) + rope part
    q_lat = jnp.einsum("bhn,rhn->bhr", q_nope, w_uk)
    s = jnp.einsum("bhr,bsr->bhs", q_lat.astype(jnp.float32), cache_ckv.astype(jnp.float32))
    s += jnp.einsum("bhp,bsp->bhs", q_rope.astype(jnp.float32), cache_krope.astype(jnp.float32))
    s *= 1.0 / math.sqrt(nope + rope_d)
    valid = jnp.arange(S) <= pos
    s = jnp.where(valid[None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhs,bsr->bhr", p, cache_ckv.astype(jnp.float32))  # (B, H, r)
    out = jnp.einsum("bhr,rhv->bhv", o_lat.astype(x.dtype), w_uv).reshape(B, 1, heads * v_dim)
    out = jnp.einsum("bsh,hd->bsd", out, params["wo"])
    return out, cache_ckv, cache_krope


# ------------------------------------------------------------------ cross-attn decode


def cross_decode(
    params: dict,
    x: jax.Array,
    xk: jax.Array,
    xv: jax.Array,
    *,
    heads: int,
    kv_heads: int,
    head_dim: int,
) -> jax.Array:
    """One-token cross-attention against precomputed encoder K/V (whisper decode).

    x: (B, 1, d); xk/xv: (B, S_enc, KV, hd) — computed once at prefill from the encoder
    output and carried in the decode cache (they never change during decoding).
    No positional rotation (enc-dec cross attention), no mask (every frame is visible).
    """
    B = x.shape[0]
    q = jnp.einsum("bsd,dh->bsh", x, params["wq"]).reshape(B, heads, head_dim)
    G = heads // kv_heads
    qf = (q.astype(jnp.float32) / math.sqrt(head_dim)).reshape(B, kv_heads, G, head_dim)
    s = jnp.einsum("bkgh,bskh->bkgs", qf, xk.astype(jnp.float32))
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", p, xv.astype(jnp.float32))
    out = out.reshape(B, 1, heads * head_dim).astype(x.dtype)
    return jnp.einsum("bsh,hd->bsd", out, params["wo"])
