"""Serving launcher: ``python -m repro.launch.serve --arch <id> --reduced``.

Boots the batched engine on a (reduced, CPU) model and runs a batch of synthetic
requests through prefill + decode, reporting per-phase latency.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.models import lm
from repro.serve import Engine, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key)
    sc = ServeConfig(
        max_batch=4, max_len=args.prompt_len + args.max_new + 8, temperature=args.temperature
    )
    engine = Engine(cfg, params, sc)

    prompts = [
        list(range(3 + (i % 5), 3 + (i % 5) + args.prompt_len - (i % 4))) for i in range(args.requests)
    ]
    kwargs = {}
    if cfg.encdec:
        kwargs["frames"] = jax.random.normal(key, (sc.max_batch, cfg.enc_seq, cfg.d_model), jnp.float32)
    t0 = time.time()
    outs = engine.generate(prompts, max_new_tokens=args.max_new, **kwargs)
    dt = time.time() - t0
    toks = sum(len(o) for o in outs)
    print(f"arch={cfg.name} requests={len(prompts)} new_tokens={toks} wall={dt:.2f}s ({toks/dt:.1f} tok/s)")
    for i, o in enumerate(outs[:4]):
        print(f"  req{i}: prompt={prompts[i][:6]}... -> {o[:12]}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
