"""Serving launcher.

Two modes:

  * LM serving (the original):
        python -m repro.launch.serve --arch <id> --reduced
    boots the batched engine on a (reduced, CPU) model and runs a batch of
    synthetic requests through prefill + decode, reporting per-phase latency.

  * Sketch-solve job admission (the paper's serving path):
        python -m repro.launch.serve --solve --q 16 --backend process --adaptive
    boots a :class:`repro.serve.SolveServer`, admits ``--jobs`` synthetic
    regression jobs through the async runtime engine on the chosen executor
    backend, and prints per-job + aggregate telemetry (retries, timeouts, drops,
    effective q′, simulated makespan, relative error vs the exact solve).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.analysis.annotations import sanctioned_wall_timer
from repro.configs.base import get_config
from repro.models import lm
from repro.serve import Engine, ServeConfig, SolveServer


def _latency_model(args):
    from repro import runtime as rt

    if args.latency == "lognormal":
        return rt.LognormalLatency(seed=args.seed, mean_s=args.mean_s, sigma=0.5)
    if args.latency == "heavytail":
        return rt.HeavyTailLatency(seed=args.seed, scale_s=args.mean_s, alpha=1.5)
    if args.latency == "drift":
        return rt.DriftLatency(seed=args.seed, mean_s=args.mean_s, sigma=0.35, growth=1.3)
    if args.latency == "drop":
        return rt.DropLatency(
            seed=args.seed,
            inner=rt.LognormalLatency(seed=args.seed, mean_s=args.mean_s, sigma=0.5),
            drop_prob=0.2,
        )
    raise ValueError(f"unknown latency model {args.latency!r}")


@sanctioned_wall_timer  # reports wall cost of the admitted jobs to the operator
def solve_main(args) -> int:
    from repro import runtime as rt
    from repro.core import sketches as sk, solve

    key = jax.random.PRNGKey(args.seed)
    A = jax.random.normal(key, (args.n, args.d))
    x_true = jax.random.normal(jax.random.PRNGKey(args.seed + 1), (args.d,))
    b = A @ x_true + 0.1 * jax.random.normal(jax.random.PRNGKey(args.seed + 2), (args.n,))
    x_star = solve.lstsq(A, b)
    f_star = float(solve.residual_cost(A, b, x_star))

    spec = sk.SketchSpec(args.sketch, args.m)
    cfg = rt.RuntimeConfig(
        deadline_s=args.deadline, max_retries=args.retries,
        target_error=args.target_error, max_threads=args.pool,
    )
    deadline = rt.AdaptiveDeadline(warmup_s=args.deadline) if args.adaptive else None
    server = SolveServer(
        latency=_latency_model(args), config=cfg, backend=args.backend, deadline=deadline,
    )

    t0 = time.time()
    for j in range(args.jobs):
        job = server.submit_solve(
            A, b, spec, q=args.q, seed=args.seed + 17 * j, error_fn="probe",
        )
        f = float(solve.residual_cost(A, b, jnp.asarray(job.xbar, A.dtype)))
        rel = (f - f_star) / max(f_star, 1e-30)
        s = job.summary
        print(
            f"job {job.job_id}: q'={s['effective_q']}/{args.q} retries={s['retries']} "
            f"timeouts={s['timeouts']} drops={s['drops']} "
            f"makespan={s['sim_makespan_s']:.2f}s rel_err={rel:.3e}"
        )
    wall = time.time() - t0
    agg = server.telemetry()
    print(
        f"backend={agg['backend']} jobs={agg['jobs']} wall={wall:.2f}s "
        f"mean_q'={agg['effective_q_mean']:.1f} retries={agg['retries']} "
        f"timeouts={agg['timeouts']} drops={agg['drops']} "
        f"adaptive_deadline={bool(args.adaptive)}"
    )
    return 0


@sanctioned_wall_timer  # reports tok/s to the operator
def lm_main(args) -> int:
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key)
    sc = ServeConfig(
        max_batch=4, max_len=args.prompt_len + args.max_new + 8, temperature=args.temperature
    )
    engine = Engine(cfg, params, sc)

    prompts = [
        list(range(3 + (i % 5), 3 + (i % 5) + args.prompt_len - (i % 4))) for i in range(args.requests)
    ]
    kwargs = {}
    if cfg.encdec:
        kwargs["frames"] = jax.random.normal(key, (sc.max_batch, cfg.enc_seq, cfg.d_model), jnp.float32)
    t0 = time.time()
    outs = engine.generate(prompts, max_new_tokens=args.max_new, **kwargs)
    dt = time.time() - t0
    toks = sum(len(o) for o in outs)
    print(f"arch={cfg.name} requests={len(prompts)} new_tokens={toks} wall={dt:.2f}s ({toks/dt:.1f} tok/s)")
    for i, o in enumerate(outs[:4]):
        print(f"  req{i}: prompt={prompts[i][:6]}... -> {o[:12]}")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="LM mode: architecture id")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    # ------------------------------------------------ sketch-solve serving mode
    ap.add_argument("--solve", action="store_true", help="admit sketch-solve jobs")
    ap.add_argument("--n", type=int, default=4096)
    ap.add_argument("--d", type=int, default=32)
    ap.add_argument("--m", type=int, default=256)
    ap.add_argument("--q", type=int, default=16)
    ap.add_argument("--jobs", type=int, default=3)
    ap.add_argument("--sketch", default="gaussian")
    ap.add_argument("--backend", default="thread", choices=("inline", "thread", "process"))
    ap.add_argument("--pool", type=int, default=4, help="executor pool width")
    ap.add_argument("--latency", default="lognormal",
                    choices=("lognormal", "heavytail", "drift", "drop"))
    ap.add_argument("--mean-s", type=float, default=1.0, help="latency scale/median")
    ap.add_argument("--deadline", type=float, default=2.0)
    ap.add_argument("--retries", type=int, default=2)
    ap.add_argument("--adaptive", action="store_true", help="rolling-p95 deadlines")
    ap.add_argument("--target-error", type=float, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.solve:
        return solve_main(args)
    if args.arch is None:
        ap.error("pass --arch <id> (LM serving) or --solve (sketch-solve serving)")
    return lm_main(args)


if __name__ == "__main__":
    raise SystemExit(main())
