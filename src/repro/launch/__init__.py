"""Launchers: production meshes, multi-pod dry-run, train/serve entry points."""
