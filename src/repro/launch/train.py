"""Training launcher: ``python -m repro.launch.train --arch <id> [--reduced]``.

On this CPU container it trains reduced configs end-to-end (the examples use it);
pointed at a real TPU slice it builds the production mesh and shards per
``distributed.sharding`` — the code path is identical, only the mesh differs.
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.analysis.annotations import sanctioned_wall_timer
from repro.configs.base import get_config
from repro.optim import AdamWConfig
from repro.optim.schedules import linear_warmup_cosine
from repro.train import Trainer, TrainerConfig


@sanctioned_wall_timer  # reports end-to-end training wall cost to the operator
def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", help="CPU-size config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    opt_cfg = AdamWConfig(lr=args.lr)
    tc = TrainerConfig(
        seed=args.seed,
        batch=args.batch,
        seq=args.seq,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
        accum_steps=args.accum,
        log_every=max(1, args.steps // 20),
    )
    schedule = linear_warmup_cosine(max(1, args.steps // 10), args.steps)
    trainer = Trainer(cfg, opt_cfg, tc, schedule=schedule)

    t0 = time.time()
    state = trainer.run(args.steps)
    dt = time.time() - t0
    print(f"arch={cfg.name} steps={args.steps} wall={dt:.1f}s")
    for h in trainer.history:
        print("  " + " ".join(f"{k}={v:.4f}" if isinstance(v, float) else f"{k}={v}" for k, v in h.items()))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
