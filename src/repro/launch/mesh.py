"""Production meshes.

Functions (not module constants) so importing this file never touches jax device
state — the dry-run must set XLA_FLAGS before the first device query.

Mesh shapes (TPU v5e target):
  * single pod : (16, 16)    axes ("data", "model")   = 256 chips
  * multi-pod  : (2, 16, 16) axes ("pod", "data", "model") = 512 chips

Axis roles: the batch shards over ("pod", "data") — pure DP across pods keeps the
only cross-pod (DCN) collective the gradient reduce; "model" carries Megatron TP
within a pod's ICI domain. FSDP (ZeRO-3 parameter sharding) rides the "data" axis.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

from repro.distributed.sharding import ShardingRules


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def production_rules(*, multi_pod: bool = False) -> ShardingRules:
    dp = ("pod", "data") if multi_pod else ("data",)
    return ShardingRules(dp=dp, fsdp="data", tensor="model")


def make_smoke_mesh(n_devices: int = 0) -> Mesh:
    """A tiny mesh over whatever devices exist (tests; 1 device -> (1,1))."""
    n = n_devices or len(jax.devices())
    model = 1
    for cand in (4, 2, 1):
        if n % cand == 0 and cand <= n:
            model = cand
            break
    return jax.make_mesh((n // model, model), ("data", "model"))
