import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first init. The
# 512 host devices exist ONLY for this dry-run process — tests/benches see 1 device.

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.analysis.annotations import sanctioned_wall_timer
from repro.configs.base import ArchConfig, SHAPES, ShapeSpec, get_config, shape_applicable
from repro.configs import ASSIGNED
from repro.data.specs import input_specs, batch_pspecs
from repro.distributed.sharding import ShardingRules, cache_pspecs, param_pspecs
from repro.launch.mesh import make_production_mesh, production_rules
from repro.models import lm
from repro.optim import AdamWConfig
from repro.roofline import collectives as C
from repro.roofline.hw import V5E
from repro.roofline.model import extrapolate as _extrapolate_rl, extrapolate_cell, model_flops_for
from repro.train.state import train_state_shapes, train_state_pspecs
from repro.train.step import make_train_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "results", "dryrun")

"""Multi-pod dry-run: lower + compile every (arch × shape) on the production meshes.

Two lowerings per cell:

  * **exec form** — rolled scans, production chunk sizes: `memory_analysis()` proves
    the step fits per-device HBM (buffer reuse across scan trips is real here).
  * **analysis forms** — XLA's HLO cost analysis counts a `while` body ONCE, so the
    exec form under-reports FLOPs/bytes/collectives by ~L. We therefore compile two
    depth-reduced, fully-unrolled variants (L1, L2 layers, single-trip chunk sizes)
    and extrapolate linearly in L:  total(L) = f(L1) + (f(L2)-f(L1))/(L2-L1)·(L-L1).
    These lowerings are never executed, so their tile sizes don't matter.

Per-arch tuning knobs (accum_steps, moment_dtype, remat) live in ``TRAIN_TUNING`` —
these are the levers §Perf hillclimbs.
"""


@dataclasses.dataclass
class TrainTuning:
    accum_steps: int = 1
    moment_dtype: str = "float32"
    remat: str = "full"
    loss_chunk: int = 512
    attn_chunk: int = 1024
    ssm_chunk: int = 128
    accum_dtype: str = "float32"


TRAIN_TUNING: Dict[str, TrainTuning] = {
    # grok-314b cannot hold f32 moments (9.8 GB/chip) plus activations in 16 GB;
    # the f32 accum buffer alone is 4.9 GB/chip -> bf16 accumulation
    "grok-1-314b": TrainTuning(accum_steps=16, moment_dtype="bfloat16", accum_dtype="bfloat16"),
    "pixtral-12b": TrainTuning(accum_steps=2),
    "gemma3-12b": TrainTuning(accum_steps=2),
    "mixtral-8x7b": TrainTuning(accum_steps=4),
    # SSM archs: the fused scan bounds live tensors to O(chunk); accum halves the rest
    "falcon-mamba-7b": TrainTuning(accum_steps=2, ssm_chunk=64),
    "hymba-1.5b": TrainTuning(accum_steps=2, ssm_chunk=64),
}

# Archs whose parameter+optimizer footprint needs FSDP to span the pod axis on the
# multi-pod mesh (512-way instead of 256-way parameter sharding).
POD_FSDP_ARCHS = {"grok-1-314b"}


def _mesh_tag(multi_pod: bool) -> str:
    return "pod2x16x16" if multi_pod else "pod16x16"


def _named(mesh: Mesh, tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree, is_leaf=lambda x: isinstance(x, P)
    )


def _analysis_depths(cfg: ArchConfig) -> Tuple[int, int]:
    if cfg.attn_kind == "local_global" and cfg.local_global_ratio > 0:
        p = cfg.local_global_ratio + 1
        return p, 2 * p
    return 1, 2


def _with_depth(cfg: ArchConfig, L: int) -> ArchConfig:
    changes = {"num_layers": L}
    if cfg.encdec:
        changes["enc_layers"] = L
    return dataclasses.replace(cfg, **changes)


def _lower(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh, rules: ShardingRules,
           tuning: TrainTuning, plan: lm.ExecPlan, accum_steps: int):
    """Build the jitted-and-lowered artifact for one (cfg-variant, shape)."""
    if shape.mode == "train":
        opt_cfg = AdamWConfig(moment_dtype=tuning.moment_dtype)
        step = make_train_step(
            cfg, opt_cfg, rules=rules, plan=plan, accum_steps=accum_steps,
            accum_dtype=tuning.accum_dtype,
        )
        state_shapes = train_state_shapes(cfg, opt_cfg)
        state_sh = _named(mesh, train_state_pspecs(cfg, opt_cfg, rules))
        bspecs = input_specs(cfg, shape)
        batch_sh = _named(mesh, batch_pspecs(cfg, shape, rules))
        return jax.jit(
            step,
            in_shardings=(state_sh, batch_sh),
            out_shardings=(state_sh, None),
            donate_argnums=(0,),
        ).lower(state_shapes, bspecs)
    if shape.mode == "prefill":
        pshapes = lm.param_shapes(cfg)
        params_sh = _named(mesh, param_pspecs(pshapes, rules))
        bspecs = input_specs(cfg, shape)
        batch_sh = _named(mesh, batch_pspecs(cfg, shape, rules))

        def prefill_fn(params, batch):
            return lm.batched_prefill(params, cfg, batch, cache_len=shape.seq_len, rules=rules, plan=plan)

        cache_struct = jax.eval_shape(prefill_fn, pshapes, bspecs)[1]
        cache_sh = _named(mesh, cache_pspecs(cache_struct, rules, batch_sharded=True))
        return jax.jit(
            prefill_fn,
            in_shardings=(params_sh, batch_sh),
            out_shardings=(None, cache_sh),
        ).lower(pshapes, bspecs)
    # decode
    pshapes = lm.param_shapes(cfg)
    params_sh = _named(mesh, param_pspecs(pshapes, rules))
    cache_struct = lm.cache_shapes(cfg, shape.global_batch, shape.seq_len)
    batch_sharded = shape.global_batch > 1
    cache_sh = _named(mesh, cache_pspecs(cache_struct, rules, batch_sharded=batch_sharded))
    bspecs = input_specs(cfg, shape)
    dp = rules.resolve("dp")
    tok_sh = NamedSharding(mesh, P(dp if batch_sharded else None))
    pos_sh = NamedSharding(mesh, P())

    def decode_fn(params, cache, tokens, pos):
        return lm.decode_step(params, cfg, tokens, cache, pos, rules=rules, plan=plan)

    return jax.jit(
        decode_fn,
        in_shardings=(params_sh, cache_sh, tok_sh, pos_sh),
        out_shardings=(None, cache_sh),
        donate_argnums=(1,),
    ).lower(pshapes, cache_struct, bspecs["tokens"], bspecs["pos"])


def _collective_agg(hlo: str, pod_size: Optional[int]) -> Dict[str, Dict[str, float]]:
    ops = C.parse_collectives(hlo, pod_size=pod_size)
    agg: Dict[str, Dict[str, float]] = {}
    for op in ops:
        e = agg.setdefault(op.kind, {"count": 0.0, "bytes": 0.0, "wire_bytes": 0.0, "dcn_wire_bytes": 0.0})
        wb = C.op_wire_bytes(op)
        e["count"] += 1
        e["bytes"] += op.bytes
        e["wire_bytes"] += wb
        if op.crosses_pod:
            e["dcn_wire_bytes"] += wb
    return agg


_extrapolate = _extrapolate_rl
_extrapolate_cell = extrapolate_cell


def _collective_seconds(agg) -> Dict[str, float]:
    total_s = dcn_s = wire = 0.0
    for kind, e in agg.items():
        ici_bytes = e["wire_bytes"] - e["dcn_wire_bytes"]
        t = ici_bytes / V5E.ici_link_bw + e["dcn_wire_bytes"] / V5E.dcn_bw
        total_s += t
        dcn_s += e["dcn_wire_bytes"] / V5E.dcn_bw
        wire += e["wire_bytes"]
    return {"total_s": total_s, "dcn_s": dcn_s, "wire_bytes": wire}


@sanctioned_wall_timer  # lower/compile wall costs are part of the dry-run record
def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               tuning: Optional[TrainTuning] = None,
               rules_override: Optional[ShardingRules] = None) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    base = {"arch": arch, "shape": shape_name, "mesh": _mesh_tag(multi_pod)}
    if not ok:
        return {**base, "status": "SKIP", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules_override or production_rules(multi_pod=multi_pod)
    if multi_pod and arch in POD_FSDP_ARCHS and rules_override is None:
        # 314B-class params don't fit a single pod's HBM alongside optimizer state:
        # span FSDP across pods (ZeRO-3 over DCN — param gathers ride the pod axis).
        rules = dataclasses.replace(rules, fsdp=("pod", "data"))
    chips = mesh.size
    tuning = tuning or TRAIN_TUNING.get(arch, TrainTuning())
    pod_size = 256 if multi_pod else None

    with mesh:
        # ---------------- exec form: memory truth
        exec_plan = lm.ExecPlan(
            attn_chunk=tuning.attn_chunk,
            loss_chunk=tuning.loss_chunk,
            ssm_chunk=tuning.ssm_chunk,
            remat=tuning.remat,
        )
        t0 = time.time()
        lowered = _lower(cfg, shape, mesh, rules, tuning, exec_plan, tuning.accum_steps)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_bytes": int(ma.peak_memory_in_bytes),
        }
        live = mem["argument_bytes"] + mem["temp_bytes"] + mem["output_bytes"] - mem["alias_bytes"]
        fits = live <= V5E.hbm_bytes
        del compiled, lowered

        # ---------------- analysis forms: cost truth (unrolled, depth-extrapolated)
        L1, L2 = _analysis_depths(cfg)
        L = cfg.num_layers
        a_plan = lm.analysis_plan(shape.seq_len, remat=tuning.remat)
        costs, aggs = [], []
        for Lk in (L1, L2):
            cfg_k = _with_depth(cfg, Lk)
            low_k = _lower(cfg_k, shape, mesh, rules, tuning, a_plan, 1)
            comp_k = low_k.compile()
            costs.append(dict(comp_k.cost_analysis()))
            aggs.append(_collective_agg(comp_k.as_text(), pod_size))
            del comp_k, low_k
        cost, agg = _extrapolate_cell(costs[0], costs[1], aggs[0], aggs[1], L1, L2, L)

    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    coll = _collective_seconds(agg)
    compute_s = flops / V5E.peak_flops_bf16
    memory_s = nbytes / V5E.hbm_bw
    collective_s = coll["total_s"]
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    step_s = max(terms.values())
    mf = model_flops_for(cfg, shape, mode=shape.mode)
    return {
        **base,
        "status": "OK",
        "chips": chips,
        "mode": shape.mode,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": mem,
        "fits_16gb_hbm": bool(fits),
        "cost": cost,
        "collectives": agg,
        "roofline": {
            "compute_s": compute_s,
            "memory_s": memory_s,
            "collective_s": collective_s,
            "bottleneck": bottleneck,
            "step_s": step_s,
            "model_flops": mf,
            "useful_fraction": mf / max(flops * chips, 1.0),
            "roofline_fraction": compute_s / step_s if step_s > 0 else 0.0,
            "collective_detail": coll,
        },
        "tuning": dataclasses.asdict(tuning) if shape.mode == "train" else None,
    }


def run_cells(archs, shapes, meshes, out_dir: str, *, resume: bool = True):
    os.makedirs(out_dir, exist_ok=True)
    results = []
    for arch in archs:
        for shape_name in shapes:
            for mesh_name in meshes:
                multi_pod = mesh_name == "multi"
                tag = f"{arch}_{shape_name}_{_mesh_tag(multi_pod)}"
                path = os.path.join(out_dir, tag + ".json")
                if resume and os.path.exists(path):
                    with open(path) as f:
                        rec = json.load(f)
                    print(f"[dryrun] {tag}: cached ({rec['status']})", flush=True)
                    results.append(rec)
                    continue
                print(f"[dryrun] {tag}: lowering...", flush=True)
                try:
                    rec = lower_cell(arch, shape_name, multi_pod=multi_pod)
                except Exception as e:
                    rec = {
                        "arch": arch, "shape": shape_name, "mesh": _mesh_tag(multi_pod),
                        "status": "FAIL",
                        "error": f"{type(e).__name__}: {e}",
                        "traceback": traceback.format_exc()[-4000:],
                    }
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                if rec["status"] == "OK":
                    m, r = rec["memory"], rec["roofline"]
                    print(
                        f"[dryrun] {tag}: OK compile={rec['compile_s']}s "
                        f"args={m['argument_bytes']/2**30:.2f}GiB temp={m['temp_bytes']/2**30:.2f}GiB "
                        f"fits={rec['fits_16gb_hbm']} bottleneck={r['bottleneck']} "
                        f"terms=({r['compute_s']*1e3:.1f},{r['memory_s']*1e3:.1f},{r['collective_s']*1e3:.1f})ms "
                        f"useful={r['useful_fraction']:.2f} roofline={r['roofline_fraction']:.2f}",
                        flush=True,
                    )
                else:
                    print(f"[dryrun] {tag}: {rec['status']} {rec.get('reason', rec.get('error',''))}", flush=True)
                results.append(rec)
    return results


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", help="shape name or 'all'")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default=os.path.normpath(RESULTS_DIR))
    ap.add_argument("--no-resume", action="store_true")
    args = ap.parse_args()

    archs = ASSIGNED if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    results = run_cells(archs, shapes, meshes, args.out, resume=not args.no_resume)
    n_ok = sum(r["status"] == "OK" for r in results)
    n_skip = sum(r["status"] == "SKIP" for r in results)
    n_fail = sum(r["status"] == "FAIL" for r in results)
    print(f"[dryrun] done: {n_ok} OK, {n_skip} SKIP, {n_fail} FAIL of {len(results)}")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
