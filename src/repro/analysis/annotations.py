"""Source annotations the rules recognize.

These are *markers*: they change nothing at runtime beyond an attribute, but the
static rules key off their (resolved) names. Keeping them importable costs
nothing — launch/benchmark code imports the decorator for real so refactors that
rename it break loudly instead of silently detaching the allowlist.
"""
from __future__ import annotations

from typing import Callable, TypeVar

F = TypeVar("F", bound=Callable)

#: Resolved decorator name the ``wallclock-in-runtime`` rule honors.
SANCTIONED_WALL_TIMER = "sanctioned_wall_timer"


def sanctioned_wall_timer(fn: F) -> F:
    """Allowlist ``fn`` as a sanctioned wall-cost timer.

    Launch entry points and benchmarks legitimately measure *wall cost* — how long
    the hardware took — and report it to a human. That is the only sanctioned use
    of wall-clock reads, and only under ``launch/`` and ``benchmarks/``: inside
    ``runtime/``, ``serve/`` or ``core/`` a wall-clock read can leak into event
    *ordering* and break the same-seed ⇒ byte-identical-log guarantee, so the rule
    ignores this decorator there (fix the code or baseline it, don't sanction it).
    """
    fn.__reprolint_wall_timer__ = True
    return fn
