"""Rule registry and the Finding record.

A rule is a class with a unique kebab-case ``name``, a one-line ``description``
(shown by ``repro-lint --list-rules`` and in the README rule table), and a
``check(module)`` generator yielding :class:`Finding`s. Registration happens at
import time via the :func:`register` decorator; ``repro.analysis.rules``
imports every rule module so :func:`all_rules` sees the full set.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Iterator, List, Optional, Type

from repro.analysis.walker import Module


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One rule violation at one source location."""

    path: str
    line: int
    col: int
    rule: str
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: [{self.rule}] {self.message}"


class Rule:
    """Base class; subclasses set ``name``/``description`` and implement check()."""

    name: str = ""
    description: str = ""

    def check(self, module: Module) -> Iterator[Finding]:
        raise NotImplementedError

    def finding(self, module: Module, node, message: str) -> Finding:
        return Finding(
            path=module.path,
            line=getattr(node, "lineno", 0),
            col=getattr(node, "col_offset", 0),
            rule=self.name,
            message=message,
        )


_REGISTRY: Dict[str, Type[Rule]] = {}


def register(cls: Type[Rule]) -> Type[Rule]:
    if not cls.name:
        raise ValueError(f"rule class {cls.__name__} must set a name")
    if cls.name in _REGISTRY:
        raise ValueError(f"duplicate rule name {cls.name!r}")
    _REGISTRY[cls.name] = cls
    return cls


def all_rules(select: Optional[Iterable[str]] = None) -> List[Rule]:
    """Instantiate every registered rule (or the ``select``ed subset, validated)."""
    import repro.analysis.rules  # noqa: F401  — registers the built-in rules

    if select is None:
        names = sorted(_REGISTRY)
    else:
        names = list(select)
        unknown = [n for n in names if n not in _REGISTRY]
        if unknown:
            raise KeyError(f"unknown rule(s) {unknown}; available: {sorted(_REGISTRY)}")
    return [_REGISTRY[n]() for n in names]


def rule_names() -> List[str]:
    import repro.analysis.rules  # noqa: F401

    return sorted(_REGISTRY)
