"""Committed baseline of grandfathered findings.

The baseline lets the lint gate be adopted on a tree with pre-existing findings:
everything recorded in the baseline file passes, anything *new* fails. Entries
are fingerprinted by ``(rule, path, stripped source line)`` rather than line
number, so unrelated edits that shift a grandfathered finding up or down do not
break the gate; duplicate fingerprints are counted, so adding a *second* copy of
a baselined bug still fails.

Workflow:
    python -m repro.analysis src --write-baseline    # grandfather current findings
    git add reprolint-baseline.json

The goal state is an empty baseline — fix findings and re-write it shrinking.
"""
from __future__ import annotations

import dataclasses
import json
from collections import Counter
from typing import Dict, Iterable, List, Tuple

from repro.analysis.registry import Finding

BASELINE_FILENAME = "reprolint-baseline.json"
_SCHEMA_VERSION = 1

_Key = Tuple[str, str, str]  # (rule, path, snippet)


def _key(finding: Finding, snippet: str) -> _Key:
    return (finding.rule, finding.path, snippet)


@dataclasses.dataclass
class Baseline:
    """A multiset of grandfathered finding fingerprints."""

    counts: Counter = dataclasses.field(default_factory=Counter)

    @classmethod
    def load(cls, path: str) -> "Baseline":
        """Load from ``path``; a missing file is an empty baseline."""
        try:
            with open(path, encoding="utf-8") as f:
                data = json.load(f)
        except FileNotFoundError:
            return cls()
        if not isinstance(data, dict) or "entries" not in data:
            raise ValueError(f"{path} is not a reprolint baseline file")
        counts: Counter = Counter()
        for e in data["entries"]:
            counts[(e["rule"], e["path"], e["snippet"])] += int(e.get("count", 1))
        return cls(counts=counts)

    @classmethod
    def from_findings(cls, findings: Iterable[Finding], snippets: Dict[Finding, str]) -> "Baseline":
        counts: Counter = Counter()
        for f in findings:
            counts[_key(f, snippets.get(f, ""))] += 1
        return cls(counts=counts)

    def save(self, path: str) -> None:
        entries = [
            {"rule": rule, "path": fpath, "snippet": snippet, "count": count}
            for (rule, fpath, snippet), count in sorted(self.counts.items())
        ]
        with open(path, "w", encoding="utf-8") as f:
            json.dump({"version": _SCHEMA_VERSION, "entries": entries}, f, indent=1, sort_keys=True)
            f.write("\n")

    def split(
        self, findings: List[Finding], snippets: Dict[Finding, str]
    ) -> Tuple[List[Finding], List[Finding]]:
        """Partition ``findings`` into (new, grandfathered) against this baseline."""
        remaining = Counter(self.counts)
        new: List[Finding] = []
        old: List[Finding] = []
        for f in findings:
            k = _key(f, snippets.get(f, ""))
            if remaining[k] > 0:
                remaining[k] -= 1
                old.append(f)
            else:
                new.append(f)
        return new, old

    def __len__(self) -> int:
        return sum(self.counts.values())
