"""Finding reporters: human text and machine JSON."""
from __future__ import annotations

import json
from typing import List

from repro.analysis.registry import Finding


def text_report(
    new: List[Finding],
    grandfathered: List[Finding],
    *,
    files: int,
    suppressed: int,
    verbose_grandfathered: bool = False,
) -> str:
    lines = [f.format() for f in sorted(new)]
    if verbose_grandfathered:
        lines += [f.format() + "  (baselined)" for f in sorted(grandfathered)]
    tail = (
        f"reprolint: {len(new)} finding(s) in {files} file(s)"
        f" ({len(grandfathered)} baselined, {suppressed} suppressed)"
    )
    if not new:
        tail = f"reprolint: clean — {files} file(s)" + (
            f" ({len(grandfathered)} baselined, {suppressed} suppressed)"
            if grandfathered or suppressed
            else ""
        )
    lines.append(tail)
    return "\n".join(lines)


def json_report(
    new: List[Finding],
    grandfathered: List[Finding],
    *,
    files: int,
    suppressed: int,
) -> str:
    def rec(f: Finding) -> dict:
        return {"path": f.path, "line": f.line, "col": f.col, "rule": f.rule, "message": f.message}

    return json.dumps(
        {
            "files": files,
            "suppressed": suppressed,
            "new": [rec(f) for f in sorted(new)],
            "grandfathered": [rec(f) for f in sorted(grandfathered)],
        },
        indent=1,
    )
