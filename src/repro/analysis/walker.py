"""AST plumbing shared by every reprolint rule.

The rules work on :class:`Module` objects — a parsed file plus the derived maps
every rule needs: import-alias resolution (so ``jr.normal`` and
``jax.random.normal`` look the same), parent links (so a call site can find its
enclosing function), and path classification (which repo surface a file belongs
to: ``repro.runtime`` vs ``benchmarks`` vs ``tests``).

This module is stdlib-only by design: the analyzer must import cleanly in an
environment without jax (CI lint tier, pre-commit).
"""
from __future__ import annotations

import ast
import dataclasses
from pathlib import PurePosixPath
from typing import Dict, Iterator, Optional, Tuple, Union

FunctionNode = Union[ast.FunctionDef, ast.AsyncFunctionDef]
ScopeNode = Union[ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda]


def build_alias_map(tree: ast.Module) -> Dict[str, str]:
    """Map local names to the fully qualified names their imports bind.

    ``import jax.random as jr``      -> ``{"jr": "jax.random"}``
    ``import jax``                   -> ``{"jax": "jax"}``
    ``from jax import random``       -> ``{"random": "jax.random"}``
    ``from time import time as now`` -> ``{"now": "time.time"}``

    Only module-level and function-level imports are recorded; a later import of
    the same name wins (shadowing inside one file is rare enough not to model).
    """
    aliases: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.asname:
                    aliases[a.asname] = a.name
                else:
                    head = a.name.split(".")[0]
                    aliases[head] = head
        elif isinstance(node, ast.ImportFrom) and node.module and not node.level:
            for a in node.names:
                if a.name == "*":
                    continue
                aliases[a.asname or a.name] = f"{node.module}.{a.name}"
    return aliases


def dotted_name(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for a Name/Attribute chain, None for anything else."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


@dataclasses.dataclass
class Module:
    """One parsed file plus the derived structure rules share."""

    path: str
    source: str
    tree: ast.Module
    aliases: Dict[str, str] = dataclasses.field(default_factory=dict)
    parents: Dict[ast.AST, ast.AST] = dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        self.aliases = build_alias_map(self.tree)
        self.parents = {
            child: parent for parent in ast.walk(self.tree) for child in ast.iter_child_nodes(parent)
        }
        self.lines = self.source.splitlines()

    # ------------------------------------------------------------- name resolution

    def resolve(self, node: ast.AST) -> Optional[str]:
        """Qualified dotted name of a Name/Attribute chain, through import aliases."""
        d = dotted_name(node)
        if d is None:
            return None
        head, _, rest = d.partition(".")
        base = self.aliases.get(head)
        if base is None:
            return d
        return f"{base}.{rest}" if rest else base

    def resolve_call(self, call: ast.Call) -> Optional[str]:
        return self.resolve(call.func)

    # ------------------------------------------------------------------ structure

    def enclosing_functions(self, node: ast.AST) -> Iterator[FunctionNode]:
        """Innermost-first chain of function defs containing ``node``."""
        cur = self.parents.get(node)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield cur
            cur = self.parents.get(cur)

    def decorator_names(self, fn: FunctionNode) -> Tuple[str, ...]:
        """Resolved dotted names of ``fn``'s decorators (Call decorators unwrapped:
        both ``@jit`` and ``@partial(jit, ...)`` contribute ``jit``'s name)."""
        out = []
        for dec in fn.decorator_list:
            if isinstance(dec, ast.Call):
                name = self.resolve(dec.func)
                if name:
                    out.append(name)
                for arg in dec.args:  # functools.partial(jax.jit, ...) etc.
                    inner = self.resolve(arg)
                    if inner:
                        out.append(inner)
            else:
                name = self.resolve(dec)
                if name:
                    out.append(name)
        return tuple(out)

    def snippet(self, line: int) -> str:
        """Stripped source text of a 1-indexed line (baseline fingerprints)."""
        if 1 <= line <= len(self.lines):
            return self.lines[line - 1].strip()
        return ""

    # ----------------------------------------------------------- path classification

    @property
    def parts(self) -> Tuple[str, ...]:
        return PurePosixPath(self.path.replace("\\", "/")).parts

    @property
    def repro_subpackage(self) -> Optional[str]:
        """``'runtime'`` for ``src/repro/runtime/engine.py``; None outside repro/."""
        parts = self.parts
        if "repro" not in parts:
            return None
        # last occurrence: an absolute checkout path may itself contain "repro"
        i = len(parts) - 1 - parts[::-1].index("repro")
        rest = parts[i + 1 :]
        if not rest:
            return None
        return "" if rest[0].endswith(".py") else rest[0]

    @property
    def top_dir(self) -> Optional[str]:
        """First path segment (``'benchmarks'``, ``'tests'``, ``'src'``, ...)."""
        parts = self.parts
        return parts[0] if len(parts) > 1 else None

    @property
    def is_test_code(self) -> bool:
        return self.top_dir == "tests" or self.parts[-1].startswith("test_")


def parse_source(source: str, path: str) -> Module:
    """Parse ``source`` as the file at ``path`` (virtual paths fine — tests use
    them to place snippets under rule-scoped directories)."""
    tree = ast.parse(source, filename=path)
    return Module(path=path, source=source, tree=tree)


def parse_file(path: str) -> Module:
    with open(path, encoding="utf-8") as f:
        return parse_source(f.read(), path)
