"""reprolint CLI.

    python -m repro.analysis [paths ...]        # default: src tests benchmarks
    repro-lint src --json
    repro-lint src --write-baseline             # grandfather current findings
    repro-lint --list-rules

Exit codes: 0 clean (everything suppressed/baselined), 1 new findings,
2 usage or parse errors.
"""
from __future__ import annotations

import argparse
import os
import sys
from typing import List, Optional

from repro.analysis.baseline import BASELINE_FILENAME, Baseline
from repro.analysis.engine import run
from repro.analysis.registry import all_rules
from repro.analysis.reporters import json_report, text_report

DEFAULT_PATHS = ("src", "tests", "benchmarks")


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="repro-lint",
        description="AST lint for the RNG-privacy, determinism, and kernel/pickle contracts",
    )
    ap.add_argument(
        "paths",
        nargs="*",
        help=f"files/directories to analyze (default: the existing ones of {' '.join(DEFAULT_PATHS)})",
    )
    ap.add_argument("--json", action="store_true", help="machine-readable report")
    ap.add_argument(
        "--baseline",
        default=BASELINE_FILENAME,
        help=f"baseline file of grandfathered findings (default {BASELINE_FILENAME})",
    )
    ap.add_argument("--no-baseline", action="store_true", help="ignore the baseline file")
    ap.add_argument(
        "--write-baseline",
        action="store_true",
        help="record every current finding into the baseline file and exit 0",
    )
    ap.add_argument(
        "--select",
        default="",
        help="comma-separated subset of rules to run (default: all)",
    )
    ap.add_argument("--list-rules", action="store_true", help="print the rule table and exit")
    ap.add_argument(
        "--show-baselined",
        action="store_true",
        help="also print grandfathered findings in the text report",
    )
    return ap


def main(argv: Optional[List[str]] = None) -> int:
    ap = build_parser()
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule in all_rules():
            print(f"{rule.name}\n    {rule.description}")
        return 0

    select = [r.strip() for r in args.select.split(",") if r.strip()] or None
    try:
        if select:
            all_rules(select)  # validate early for a clean error
    except KeyError as e:
        print(f"repro-lint: {e.args[0]}", file=sys.stderr)
        return 2

    paths = args.paths or [p for p in DEFAULT_PATHS if os.path.exists(p)]
    if not paths:
        print("repro-lint: no paths given and none of the defaults exist", file=sys.stderr)
        return 2

    baseline = Baseline() if args.no_baseline else Baseline.load(args.baseline)
    report = run(paths, rules=select, baseline=Baseline() if args.write_baseline else baseline)

    for err in report.parse_errors:
        print(f"repro-lint: parse error: {err}", file=sys.stderr)

    if args.write_baseline:
        findings = report.new + report.grandfathered
        Baseline.from_findings(findings, report.snippets).save(args.baseline)
        print(f"repro-lint: wrote {len(findings)} finding(s) to {args.baseline}")
        return 0 if not report.parse_errors else 2

    if args.json:
        print(
            json_report(
                report.new,
                report.grandfathered,
                files=report.files,
                suppressed=report.suppressed,
            )
        )
    else:
        print(
            text_report(
                report.new,
                report.grandfathered,
                files=report.files,
                suppressed=report.suppressed,
                verbose_grandfathered=args.show_baselined,
            )
        )
    return report.exit_code


if __name__ == "__main__":
    raise SystemExit(main())
