"""``python -m repro.analysis`` — the reprolint CLI."""
from repro.analysis.cli import main

raise SystemExit(main())
