"""repro.analysis — "reprolint", the AST lint engine for this repo's contracts.

The paper's guarantees are runtime *conventions* in this codebase: fresh
``fold_in``-derived keys before every sketch (privacy/unbiasedness), simulated-
clock-only event ordering in the runtime (same seed ⇒ byte-identical logs),
picklable numpy-state task specs (process backend), and tracer-safe Pallas/jit
bodies. This package machine-checks them:

    python -m repro.analysis src tests benchmarks
    repro-lint --list-rules

Five rules: ``rng-key-reuse``, ``wallclock-in-runtime``, ``trace-hazard``,
``env-read-in-trace``, ``unpicklable-task-spec``. Per-line suppressions
(``# reprolint: disable=<rule>``), a committed baseline for grandfathered
findings (``reprolint-baseline.json``), text/JSON reporters.

Stdlib-only on purpose: the lint tier runs without importing jax.
"""
from repro.analysis.annotations import sanctioned_wall_timer
from repro.analysis.baseline import BASELINE_FILENAME, Baseline
from repro.analysis.engine import Report, analyze_source, check_module, collect_files, run
from repro.analysis.registry import Finding, Rule, all_rules, register, rule_names
from repro.analysis.walker import Module, parse_file, parse_source

__all__ = [
    "BASELINE_FILENAME",
    "Baseline",
    "Finding",
    "Module",
    "Report",
    "Rule",
    "all_rules",
    "analyze_source",
    "check_module",
    "collect_files",
    "parse_file",
    "parse_source",
    "register",
    "rule_names",
    "run",
    "sanctioned_wall_timer",
]
