"""unpicklable-task-spec — process-backend task specs must hold picklable state.

The ``process`` executor backend ships one task-spec payload to each spawned
worker via the pool initializer; from then on only bare ``(worker_id, round_id)``
coordinates cross the boundary. That works because the specs follow the
``runtime/tasks.py`` convention: plain classes over **numpy** state, with the jit
cache rebuilt lazily per process (``_fn = None`` in ``__getstate__``). A lambda,
a closure, a lock, or a ``jax.Array`` field silently breaks pickling — the
failure shows up as a cryptic spawn-time crash on exactly the backend the tests
exercise least.

Detection: classes that subclass (transitively, within the module) a class named
``_PicklableCompute``/``PicklableCompute``, or that carry a ``task_spec`` marker
decorator. Inside their methods, ``self.x = <lambda>``, ``self.x = <local def>``,
``self.x = threading.Lock()``-family, and ``self.x = jnp./jax. <call>`` are
findings. ``np.asarray(...)`` fields are the sanctioned pattern.

Scope: everywhere except ``tests/`` (fault-injection tests build deliberately
broken specs).
"""
from __future__ import annotations

import ast
from typing import Iterator, List, Set

from repro.analysis.registry import Finding, Rule, register
from repro.analysis.walker import Module

_BASE_NAMES = {"_PicklableCompute", "PicklableCompute"}
_MARKER_DECORATOR = "task_spec"
_LOCK_CALLS = {
    "threading.Lock",
    "threading.RLock",
    "threading.Event",
    "threading.Condition",
    "threading.Semaphore",
    "threading.BoundedSemaphore",
    "multiprocessing.Lock",
    "multiprocessing.RLock",
}
_DEVICE_HEADS = ("jax.", "jnp.")


def _base_names(cls: ast.ClassDef, module: Module) -> List[str]:
    out = []
    for b in cls.bases:
        name = module.resolve(b)
        if name:
            out.append(name.split(".")[-1])
    return out


def _task_spec_classes(module: Module) -> List[ast.ClassDef]:
    classes = [n for n in ast.walk(module.tree) if isinstance(n, ast.ClassDef)]
    spec_names: Set[str] = set(_BASE_NAMES)
    # transitive closure over module-local inheritance (tiny graphs; loop to fixpoint)
    changed = True
    while changed:
        changed = False
        for c in classes:
            if c.name in spec_names:
                continue
            if any(b in spec_names for b in _base_names(c, module)):
                spec_names.add(c.name)
                changed = True
    out = []
    for c in classes:
        marked = any(d.split(".")[-1] == _MARKER_DECORATOR for d in _decorators(c, module))
        if marked or c.name in spec_names:
            out.append(c)
    return out


def _decorators(cls: ast.ClassDef, module: Module) -> List[str]:
    out = []
    for dec in cls.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        name = module.resolve(target)
        if name:
            out.append(name)
    return out


@register
class UnpicklableTaskSpecRule(Rule):
    name = "unpicklable-task-spec"
    description = (
        "process-backend task spec holds a lambda/closure/lock/jax.Array field — "
        "specs must be numpy-state picklable (runtime/tasks.py convention)"
    )

    def check(self, module: Module) -> Iterator[Finding]:
        if module.is_test_code:
            return
        for cls in _task_spec_classes(module):
            local_defs: Set[str] = set()
            for method in cls.body:
                if not isinstance(method, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    continue
                for stmt in ast.walk(method):
                    if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)) and stmt is not method:
                        local_defs.add(stmt.name)
                for stmt in ast.walk(method):
                    if not isinstance(stmt, ast.Assign):
                        continue
                    for target in stmt.targets:
                        field = self._self_field(target)
                        if field is None:
                            continue
                        why = self._offending(stmt.value, module, local_defs)
                        if why:
                            yield self.finding(
                                module,
                                stmt,
                                f"task spec `{cls.name}` field `self.{field}` holds {why} — "
                                "the process backend pickles specs; keep numpy state only "
                                "and rebuild jits lazily (see runtime/tasks.py)",
                            )

    @staticmethod
    def _self_field(target: ast.AST) -> str | None:
        if (
            isinstance(target, ast.Attribute)
            and isinstance(target.value, ast.Name)
            and target.value.id == "self"
        ):
            return target.attr
        return None

    @staticmethod
    def _offending(value: ast.AST, module: Module, local_defs: Set[str]) -> str | None:
        if isinstance(value, ast.Lambda):
            return "a lambda"
        if isinstance(value, ast.Name) and value.id in local_defs:
            return f"the local closure `{value.id}`"
        if isinstance(value, ast.Call):
            resolved = module.resolve_call(value) or ""
            if resolved in _LOCK_CALLS:
                return f"a `{resolved}` (unpicklable synchronization primitive)"
            if resolved.startswith(_DEVICE_HEADS) or resolved in ("jax", "jnp"):
                return f"a jax value (`{resolved}(...)`) — device arrays don't pickle"
        return None
