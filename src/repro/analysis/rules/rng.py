"""rng-key-reuse — every sketch/draw consumes a fresh PRNG key.

Paper guarantee this protects: **privacy and unbiasedness**. Both the (ε,δ)
privacy argument and the Theorem-1 error decay require each worker, round, and
retry to draw a **fresh i.i.d. sketch**: E[x̄] telescopes only over independent
S_k, and reusing a key re-releases the *same* randomized projection — the
privacy amplification from averaging q independent releases silently collapses.
The repo's convention is ``fold_in``/``split`` before every draw
(``prng.worker_key(base_key, w, round)``); this rule machine-checks it.

Detection (per function scope, linear statement walk):

  * *key variables*: names bound from ``jax.random.PRNGKey/key/fold_in/split``,
    ``worker_key(s)``, or key-ish parameters (``key``, ``wkey``, ``rng``,
    ``*_key``). Tuple-unpacking a ``split`` marks every target.
  * *consumers*: ``jax.random.<sampler>`` calls and the sketch entry points
    (``make_operator``, ``sketch_and_solve``, ``sketch_least_norm``, ``ihs``)
    with a key variable passed bare.
  * a second consumption of the same name with no intervening rebinding is a
    finding. Loop bodies (and comprehensions) are walked twice, so a draw inside
    a loop whose key isn't re-derived per iteration is caught as cross-iteration
    reuse; ``if``/``else`` branches are walked independently (exclusive paths may
    each consume the key once).

Scope: everywhere except ``tests/`` (parity tests reuse keys on purpose;
benchmark parity call sites use per-line suppressions instead, so the exceptions
stay visible in the diff).
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, Iterator, List, Optional, Set, Tuple, Union

from repro.analysis.registry import Finding, Rule, register
from repro.analysis.walker import Module

SAMPLERS = {
    "ball",
    "bernoulli",
    "beta",
    "binomial",
    "bits",
    "categorical",
    "cauchy",
    "chisquare",
    "choice",
    "dirichlet",
    "double_sided_maxwell",
    "exponential",
    "gamma",
    "geometric",
    "gumbel",
    "laplace",
    "loggamma",
    "logistic",
    "lognormal",
    "maxwell",
    "multivariate_normal",
    "normal",
    "orthogonal",
    "pareto",
    "permutation",
    "poisson",
    "rademacher",
    "randint",
    "rayleigh",
    "t",
    "truncated_normal",
    "uniform",
    "weibull_min",
}

#: sketch entry points that consume a key (draw S from it) — last dotted segment.
SKETCH_CONSUMERS = {"make_operator", "sketch_and_solve", "sketch_least_norm", "ihs"}

#: jax.random calls that *derive* keys instead of consuming them.
DERIVERS = {"fold_in", "split", "clone", "key_data", "wrap_key_data"}

_KEY_PRODUCER_SUFFIXES = ("worker_key", "worker_keys", "split_tree")
_KEYISH_PARAMS = ("key", "wkey", "rng")


def _is_keyish_param(name: str) -> bool:
    return name in _KEYISH_PARAMS or name.endswith("_key") or name.endswith("key")


@dataclasses.dataclass
class _State:
    """Per-scope tracking: which names are keys, and who consumed them where."""

    keys: Set[str] = dataclasses.field(default_factory=set)
    consumed: Dict[str, int] = dataclasses.field(default_factory=dict)

    def clone(self) -> "_State":
        return _State(keys=set(self.keys), consumed=dict(self.consumed))

    def merge(self, *others: "_State") -> None:
        for o in others:
            self.keys |= o.keys
            for name, line in o.consumed.items():
                self.consumed.setdefault(name, line)


@register
class RngKeyReuseRule(Rule):
    name = "rng-key-reuse"
    description = (
        "a jax.random key consumed by two sketch/draw call sites without an "
        "intervening fold_in/split — each sketch must be i.i.d. fresh "
        "(privacy + unbiasedness both require it)"
    )

    def check(self, module: Module) -> Iterator[Finding]:
        if module.is_test_code:
            return
        self._module = module
        self._findings: Dict[Tuple[int, str], Finding] = {}
        # module top level is a scope too
        self._run_scope(module.tree.body, params=())
        for node in ast.walk(module.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._run_scope(node.body, params=self._param_names(node))
        yield from sorted(self._findings.values())

    @staticmethod
    def _param_names(fn: Union[ast.FunctionDef, ast.AsyncFunctionDef]) -> Tuple[str, ...]:
        args = fn.args
        names = [a.arg for a in list(args.posonlyargs) + list(args.args) + list(args.kwonlyargs)]
        return tuple(n for n in names if _is_keyish_param(n))

    def _run_scope(self, body: List[ast.stmt], params: Tuple[str, ...]) -> None:
        state = _State(keys=set(params))
        self._walk_stmts(body, state)

    # ------------------------------------------------------------- statement walk

    def _walk_stmts(self, stmts: List[ast.stmt], state: _State) -> None:
        for stmt in stmts:
            self._walk_stmt(stmt, state)

    def _walk_stmt(self, stmt: ast.stmt, state: _State) -> None:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            return  # nested scopes are analyzed on their own
        if isinstance(stmt, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            value = stmt.value
            if value is not None:
                self._visit_expr(value, state)
            targets = stmt.targets if isinstance(stmt, ast.Assign) else [stmt.target]
            produces = value is not None and self._produces_key(value, state)
            for t in targets:
                self._bind_target(t, produces, state)
            return
        if isinstance(stmt, (ast.For, ast.AsyncFor)):
            self._visit_expr(stmt.iter, state)
            self._bind_target(stmt.target, self._produces_key(stmt.iter, state), state)
            # two passes simulate consecutive iterations: a draw whose key isn't
            # re-derived inside the body collides with itself on pass two.
            self._walk_stmts(stmt.body, state)
            self._walk_stmts(stmt.body, state)
            self._walk_stmts(stmt.orelse, state)
            return
        if isinstance(stmt, ast.While):
            self._visit_expr(stmt.test, state)
            self._walk_stmts(stmt.body, state)
            self._walk_stmts(stmt.body, state)
            self._walk_stmts(stmt.orelse, state)
            return
        if isinstance(stmt, ast.If):
            self._visit_expr(stmt.test, state)
            then_state, else_state = state.clone(), state.clone()
            self._walk_stmts(stmt.body, then_state)
            self._walk_stmts(stmt.orelse, else_state)
            state.merge(then_state, else_state)
            return
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                self._visit_expr(item.context_expr, state)
            self._walk_stmts(stmt.body, state)
            return
        if isinstance(stmt, ast.Try):
            self._walk_stmts(stmt.body, state)
            for h in stmt.handlers:
                self._walk_stmts(h.body, state)
            self._walk_stmts(stmt.orelse, state)
            self._walk_stmts(stmt.finalbody, state)
            return
        if isinstance(stmt, (ast.Return, ast.Expr, ast.Raise, ast.Assert, ast.Delete)):
            for child in ast.iter_child_nodes(stmt):
                if isinstance(child, ast.expr):
                    self._visit_expr(child, state)
            return
        # pass/break/continue/import/global — nothing to do

    def _bind_target(self, target: ast.AST, produces_key: bool, state: _State) -> None:
        if isinstance(target, ast.Name):
            state.consumed.pop(target.id, None)
            if produces_key:
                state.keys.add(target.id)
            else:
                state.keys.discard(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                self._bind_target(elt, produces_key, state)
        # attribute/subscript targets don't rebind tracked names

    def _produces_key(self, value: ast.AST, state: _State) -> bool:
        if isinstance(value, ast.Call):
            resolved = self._module.resolve_call(value) or ""
            last = resolved.split(".")[-1]
            if resolved.startswith("jax.random.") and (last in DERIVERS or last in ("PRNGKey", "key")):
                return True
            if resolved.endswith(_KEY_PRODUCER_SUFFIXES):
                return True
            return False
        if isinstance(value, ast.Name):
            return value.id in state.keys  # aliasing: `k2 = key` keeps key-ness
        if isinstance(value, (ast.Tuple, ast.List)):
            return any(self._produces_key(e, state) for e in value.elts)
        if isinstance(value, ast.Subscript):
            return self._produces_key(value.value, state)
        return False

    # ------------------------------------------------------------ expression walk

    def _visit_expr(self, expr: ast.AST, state: _State) -> None:
        for node in self._walk_no_nested_scope(expr):
            if isinstance(node, ast.Call):
                self._visit_call(node, state)
            elif isinstance(
                node, (ast.ListComp, ast.SetComp, ast.DictComp, ast.GeneratorExp)
            ):
                # comprehension == loop: element expr walked twice
                masked = state.clone()
                for gen in node.generators:
                    self._visit_expr(gen.iter, masked)
                    self._mask_target(gen.target, masked)
                elts = (
                    [node.key, node.value] if isinstance(node, ast.DictComp) else [node.elt]
                )
                for elt in elts:
                    self._visit_expr(elt, masked)
                    self._visit_expr(elt, masked)
                state.merge(masked)

    def _mask_target(self, target: ast.AST, state: _State) -> None:
        if isinstance(target, ast.Name):
            state.keys.discard(target.id)
            state.consumed.pop(target.id, None)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                self._mask_target(elt, state)

    @staticmethod
    def _walk_no_nested_scope(expr: ast.AST):
        """ast.walk, but don't descend into lambdas/comprehensions (handled above)
        or nested function defs."""
        stack = [expr]
        while stack:
            node = stack.pop()
            yield node
            if isinstance(
                node,
                (ast.Lambda, ast.ListComp, ast.SetComp, ast.DictComp, ast.GeneratorExp,
                 ast.FunctionDef, ast.AsyncFunctionDef),
            ):
                continue
            stack.extend(ast.iter_child_nodes(node))

    def _visit_call(self, call: ast.Call, state: _State) -> None:
        resolved = self._module.resolve_call(call) or ""
        last = resolved.split(".")[-1]
        is_sampler = resolved.startswith("jax.random.") and last in SAMPLERS
        is_sketch = last in SKETCH_CONSUMERS
        if not (is_sampler or is_sketch):
            return
        key_args = []
        for arg in list(call.args) + [kw.value for kw in call.keywords]:
            if isinstance(arg, ast.Name) and arg.id in state.keys:
                key_args.append(arg)
        for arg in key_args:
            prior = state.consumed.get(arg.id)
            if prior is not None:
                f = self.finding(
                    self._module,
                    call,
                    f"PRNG key `{arg.id}` already consumed at line {prior} — "
                    "fold_in/split before drawing again: every sketch must be a "
                    "fresh i.i.d. draw (privacy + unbiasedness)",
                )
                self._findings.setdefault((f.line, arg.id), f)
            else:
                state.consumed[arg.id] = call.lineno
