"""wallclock-in-runtime — no wall-clock reads where event ordering is decided.

Paper guarantee this protects: **reproducibility of the serverless run**. The
runtime engine's contract is "same seed ⇒ byte-identical event log + bitwise
x̄" — ordering comes only from the simulated clock of a seeded LatencyModel,
never from the machine. A single ``time.time()`` / ``perf_counter()`` read that
feeds a deadline, a queue priority, or a telemetry record silently re-introduces
host scheduling into the event order, and ``os.urandom`` is wall-clock's evil
twin for the RNG contract.

Scope:
  * ``repro/runtime``, ``repro/serve``, ``repro/core`` — *strict*: every
    wall-clock read is a finding; the allowlist decorator is deliberately NOT
    honored here (use the simulated clock; a reviewed exception goes in the
    baseline, not an annotation).
  * ``repro/launch`` and top-level ``benchmarks/`` — wall-*cost* reporting to a
    human is legitimate, but must be explicit: reads are findings unless the
    enclosing function is decorated ``@sanctioned_wall_timer``
    (``repro.analysis.annotations``).
  * everywhere else — not checked.
"""
from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.annotations import SANCTIONED_WALL_TIMER
from repro.analysis.registry import Finding, Rule, register
from repro.analysis.walker import Module

WALL_CALLS = {
    "time.time",
    "time.time_ns",
    "time.perf_counter",
    "time.perf_counter_ns",
    "time.monotonic",
    "time.monotonic_ns",
    "time.process_time",
    "time.process_time_ns",
    "datetime.datetime.now",
    "datetime.datetime.utcnow",
    "datetime.datetime.today",
    "datetime.date.today",
    "os.urandom",
}

STRICT_SUBPACKAGES = {"runtime", "serve", "core"}
SANCTIONABLE_SUBPACKAGES = {"launch"}
SANCTIONABLE_TOP_DIRS = {"benchmarks"}


@register
class WallclockRule(Rule):
    name = "wallclock-in-runtime"
    description = (
        "wall-clock reads (time.time/perf_counter/datetime.now/os.urandom) in "
        "runtime/serve/core, or unsanctioned ones in launch/benchmarks — event "
        "ordering must come from the simulated clock (same seed => identical log)"
    )

    def check(self, module: Module) -> Iterator[Finding]:
        sub = module.repro_subpackage
        strict = sub in STRICT_SUBPACKAGES
        sanctionable = sub in SANCTIONABLE_SUBPACKAGES or module.top_dir in SANCTIONABLE_TOP_DIRS
        if not (strict or sanctionable):
            return
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.Call):
                continue
            resolved = module.resolve_call(node)
            if resolved not in WALL_CALLS:
                continue
            if not strict and self._sanctioned(module, node):
                continue
            where = f"repro.{sub}" if sub else module.top_dir
            if strict:
                msg = (
                    f"wall-clock read `{resolved}` under {where} — ordering must come "
                    "from the simulated clock (LatencyModel); wall time breaks the "
                    "same-seed => byte-identical-log invariant"
                )
            else:
                msg = (
                    f"wall-clock read `{resolved}` outside a @{SANCTIONED_WALL_TIMER} "
                    f"function — decorate the enclosing timer function to sanction "
                    "wall-cost reporting"
                )
            yield self.finding(module, node, msg)

    @staticmethod
    def _sanctioned(module: Module, node: ast.Call) -> bool:
        for fn in module.enclosing_functions(node):
            for dec in module.decorator_names(fn):
                if dec.split(".")[-1] == SANCTIONED_WALL_TIMER:
                    return True
        return False
