"""env-read-in-trace — all env reads go through the sanctioned surface.

Several knobs (``REPRO_RNG_ROUNDS``, ``REPRO_PALLAS_INTERPRET``,
``REPRO_MESH_BATCH``) are resolved at *trace time*: whatever value the
environment holds when a function first traces is baked into the jit cache for
the life of the process. An ad-hoc ``os.environ.get`` buried in library code
makes that capture invisible and unvalidated. ``repro.utils.env`` is the single
sanctioned read surface — it validates (bad ints/bools raise a ValueError naming
the variable) and keeps every trace-time resolution point auditable in one file.

Scope: every file under ``repro/`` except ``repro/utils/env.py`` itself.
*Writes* (``os.environ["X"] = ...``) are allowed — launchers legitimately
configure XLA before importing jax; only reads are flagged.
"""
from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.registry import Finding, Rule, register
from repro.analysis.walker import Module

_READ_CALLS = {"os.getenv", "os.environ.get"}
_ENVIRON = "os.environ"
_SANCTIONED_SUFFIX = ("repro", "utils", "env.py")


@register
class EnvReadRule(Rule):
    name = "env-read-in-trace"
    description = (
        "os.environ/os.getenv read outside repro.utils.env — env knobs resolve at "
        "trace time and must go through the one validated, auditable surface"
    )

    def check(self, module: Module) -> Iterator[Finding]:
        if module.repro_subpackage is None:
            return
        if module.parts[-len(_SANCTIONED_SUFFIX) :] == _SANCTIONED_SUFFIX:
            return
        for node in ast.walk(module.tree):
            if isinstance(node, ast.Call):
                resolved = module.resolve_call(node)
                if resolved in _READ_CALLS:
                    yield self.finding(module, node, self._msg(resolved))
            elif isinstance(node, ast.Subscript) and isinstance(node.ctx, ast.Load):
                if module.resolve(node.value) == _ENVIRON:
                    yield self.finding(module, node, self._msg("os.environ[...]"))

    @staticmethod
    def _msg(what: str) -> str:
        return (
            f"environment read `{what}` in library code — route it through "
            "repro.utils.env (validated parsing, single trace-time surface)"
        )
