"""trace-hazard — host/trace boundary violations inside jit and Pallas bodies.

Three hazard classes, all of which have bitten jax codebases (the PR-8
``hadamard_matrix`` lru-cache tracer leak was this repo's turn):

  * **host sync** — ``float(x)`` / ``int(x)`` / ``x.item()`` / ``np.asarray(x)``
    on a traced value inside a jit/pallas body. Under ``jit`` this is a
    ``ConcretizationTypeError`` at best and a silent recompile-per-value at
    worst; in a Pallas kernel it can't be lowered at all.
  * **python control flow on traced values** — ``if x > 0:`` inside a traced
    body branches at *trace* time on a tracer. ``x.shape`` / ``x.dtype`` /
    ``x.ndim`` tests are static and fine.
  * **lru_cache over trace-dependent returns** — caching a function that builds
    ``jnp``/``jax`` values means the first call under a trace stores that trace's
    tracer (or a device array pinned to it) and replays it into every later
    trace. Cache numpy on the host; convert per call (see
    ``kernels/common.hadamard_matrix``).

Traced bodies are found syntactically: functions decorated with ``jax.jit``
(bare or via ``functools.partial``), and functions/lambdas passed as the first
argument to ``jax.jit(...)`` or ``pl.pallas_call(...)`` (unwrapping a
``functools.partial(...)`` wrapper). Host-sync and traced-``if`` checks fire
only when the offending expression references a *parameter* of the traced
function that isn't obviously static (annotated ``int``/``bool``/``float``/
``str`` parameters are skipped) — a deliberate precision/recall trade-off for a
lint gate.

Scope: everywhere except ``tests/``.
"""
from __future__ import annotations

import ast
from typing import Iterator, List, Optional, Set, Union

from repro.analysis.registry import Finding, Rule, register
from repro.analysis.walker import Module

_JIT_NAMES = {"jax.jit", "jit"}
_PALLAS_SUFFIX = "pallas_call"
_PARTIAL_NAMES = {"functools.partial", "partial"}
_CACHE_NAMES = {"functools.lru_cache", "lru_cache", "functools.cache", "cache"}
_SYNC_BUILTINS = {"float", "int", "bool", "complex"}
_SYNC_CALLS = {"np.asarray", "np.array", "numpy.asarray", "numpy.array", "jax.device_get"}
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "sharding", "itemsize"}
_STATIC_ANNOTATIONS = {"int", "bool", "float", "str", "tuple", "list", "dict"}

_Fn = Union[ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda]


def _unwrap_partial(node: ast.AST, module: Module) -> ast.AST:
    if isinstance(node, ast.Call) and (module.resolve_call(node) or "") in _PARTIAL_NAMES:
        if node.args:
            return node.args[0]
    return node


def _is_jit_call(call: ast.Call, module: Module) -> bool:
    resolved = module.resolve_call(call) or ""
    return resolved in _JIT_NAMES or resolved.split(".")[-1] == _PALLAS_SUFFIX


def _traced_functions(module: Module) -> List[_Fn]:
    """Function defs / lambdas whose bodies trace under jit or pallas_call."""
    out: List[_Fn] = []
    defs_by_name = {
        n.name: n
        for n in ast.walk(module.tree)
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
    }
    for node in ast.walk(module.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in module.decorator_names(node):
                if dec in _JIT_NAMES:
                    out.append(node)
                    break
        elif isinstance(node, ast.Call) and _is_jit_call(node, module) and node.args:
            target = _unwrap_partial(node.args[0], module)
            if isinstance(target, ast.Lambda):
                out.append(target)
            elif isinstance(target, ast.Name) and target.id in defs_by_name:
                out.append(defs_by_name[target.id])
    # dedupe, preserve order
    seen: Set[int] = set()
    uniq: List[_Fn] = []
    for fn in out:
        if id(fn) not in seen:
            seen.add(id(fn))
            uniq.append(fn)
    return uniq


def _traced_params(fn: _Fn) -> Set[str]:
    """Parameter names that may carry traced values (static-annotated ones skipped)."""
    args = fn.args
    params: Set[str] = set()
    for a in list(args.posonlyargs) + list(args.args) + list(args.kwonlyargs):
        ann = a.annotation
        if ann is not None:
            ann_src = ast.unparse(ann)
            if any(tok in _STATIC_ANNOTATIONS for tok in ann_src.replace("|", " ").split()):
                continue
        params.add(a.arg)
    if args.vararg:
        params.add(args.vararg.arg)
    return params


def _traced_names_in(expr: ast.AST, module: Module, params: Set[str]) -> List[ast.Name]:
    """Names in ``expr`` that reference traced params, excluding static accesses
    (``x.shape``...), ``len(x)``, and ``isinstance(x, ...)``."""
    hits = []
    for node in ast.walk(expr):
        if not isinstance(node, ast.Name) or node.id not in params:
            continue
        parent = module.parents.get(node)
        if isinstance(parent, ast.Attribute) and parent.attr in _STATIC_ATTRS:
            continue
        if isinstance(parent, ast.Call):
            fname = module.resolve_call(parent) or ""
            if fname in ("len", "isinstance", "type"):
                continue
        hits.append(node)
    return hits


@register
class TraceHazardRule(Rule):
    name = "trace-hazard"
    description = (
        "host sync (float()/.item()/np.asarray) or python `if` on traced values "
        "inside jit/pallas bodies, or lru_cache over functions building jax values "
        "(the tracer-leak bug class)"
    )

    def check(self, module: Module) -> Iterator[Finding]:
        if module.is_test_code:
            return
        yield from self._cached_jax_builders(module)
        for fn in _traced_functions(module):
            params = _traced_params(fn)
            if not params:
                continue
            body = fn.body if isinstance(fn.body, list) else [fn.body]
            for stmt in body:
                yield from self._scan(stmt, module, params)

    # ------------------------------------------------------------ per-body checks

    def _scan(self, root: ast.AST, module: Module, params: Set[str]) -> Iterator[Finding]:
        for node in ast.walk(root):
            if isinstance(node, (ast.If, ast.While)):
                hits = _traced_names_in(node.test, module, params)
                if hits:
                    names = ", ".join(sorted({h.id for h in hits}))
                    kind = "if" if isinstance(node, ast.If) else "while"
                    yield self.finding(
                        module,
                        node,
                        f"python `{kind}` on traced value(s) `{names}` inside a "
                        "jit/pallas body — branch at trace time with static config, "
                        "or use lax.cond/jnp.where",
                    )
            elif isinstance(node, ast.Call):
                yield from self._check_sync_call(node, module, params)

    def _check_sync_call(self, call: ast.Call, module: Module, params: Set[str]) -> Iterator[Finding]:
        resolved = module.resolve_call(call) or ""
        is_item = isinstance(call.func, ast.Attribute) and call.func.attr in ("item", "tolist")
        if is_item:
            hits = _traced_names_in(call.func.value, module, params)
            if hits or isinstance(call.func.value, ast.Name) and call.func.value.id in params:
                yield self.finding(
                    module,
                    call,
                    f"`.{call.func.attr}()` on a traced value inside a jit/pallas body — "
                    "host sync can't be lowered; keep the value on device",
                )
            return
        if resolved in _SYNC_BUILTINS or resolved in _SYNC_CALLS:
            for arg in call.args:
                if _traced_names_in(arg, module, params):
                    yield self.finding(
                        module,
                        call,
                        f"`{resolved}(...)` forces a traced value to host inside a "
                        "jit/pallas body — ConcretizationTypeError or a silent "
                        "recompile per value",
                    )
                    return

    # -------------------------------------------------------------- lru_cache leak

    def _cached_jax_builders(self, module: Module) -> Iterator[Finding]:
        for node in ast.walk(module.tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            decs = module.decorator_names(node)
            if not any(d in _CACHE_NAMES for d in decs):
                continue
            culprit = self._jax_use(node, module)
            if culprit is not None:
                yield self.finding(
                    module,
                    node,
                    f"lru_cache on `{node.name}`, which builds jax values "
                    f"(`{culprit}`) — the first call under a trace caches that "
                    "trace's value into every later trace (the hadamard_matrix "
                    "leak class); cache numpy host-side and convert per call",
                )

    @staticmethod
    def _jax_use(fn: ast.AST, module: Module) -> Optional[str]:
        returns = getattr(fn, "returns", None)
        if returns is not None:
            ann = ast.unparse(returns)
            if "jax.Array" in ann or "jnp.ndarray" in ann or "jax.numpy" in ann:
                return ann
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                resolved = module.resolve_call(node) or ""
                if resolved.startswith(("jax.", "jnp.")):
                    return resolved
        return None
