"""Built-in reprolint rules — importing this package registers all of them."""
from repro.analysis.rules import env, pickle_spec, rng, trace, wallclock  # noqa: F401
