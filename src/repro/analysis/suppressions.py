"""Per-line suppression comments.

    x = jax.random.normal(key, (n,))  # reprolint: disable=rng-key-reuse
    t0 = time.time()                  # reprolint: disable=wallclock-in-runtime,trace-hazard
    y = foo()                         # reprolint: disable=all

The comment must sit on the line the finding is reported at — for a multi-line
statement that is the line the offending *node* starts on. Suppressions are
deliberate, reviewed exceptions ("these two solves share a key because the test
is a parity check"); grandfathered findings belong in the baseline instead.
"""
from __future__ import annotations

import io
import tokenize
from typing import Dict, Set

from repro.analysis.registry import Finding

_MARKER = "reprolint:"
_DISABLE = "disable="


def suppression_map(source: str) -> Dict[int, Set[str]]:
    """Line number -> set of rule names disabled on that line ('all' disables all)."""
    out: Dict[int, Set[str]] = {}
    try:
        tokens = tokenize.generate_tokens(io.StringIO(source).readline)
        for tok in tokens:
            if tok.type != tokenize.COMMENT:
                continue
            text = tok.string.lstrip("#").strip()
            if not text.startswith(_MARKER):
                continue
            body = text[len(_MARKER) :].strip()
            if not body.startswith(_DISABLE):
                continue
            rules = {r.strip() for r in body[len(_DISABLE) :].split(",") if r.strip()}
            if rules:
                out.setdefault(tok.start[0], set()).update(rules)
    except tokenize.TokenError:
        pass  # the file parsed as AST; a tokenize hiccup only loses suppressions
    return out


def is_suppressed(finding: Finding, suppressions: Dict[int, Set[str]]) -> bool:
    rules = suppressions.get(finding.line)
    if not rules:
        return False
    return "all" in rules or finding.rule in rules
