"""Analyzer orchestration: collect files, run rules, apply suppressions + baseline."""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.analysis.baseline import Baseline
from repro.analysis.registry import Finding, Rule, all_rules
from repro.analysis.suppressions import is_suppressed, suppression_map
from repro.analysis.walker import Module, parse_file, parse_source

_SKIP_DIRS = {"__pycache__", ".git", ".venv", "node_modules", ".claude"}


def collect_files(paths: Sequence[str]) -> List[str]:
    """Every ``.py`` file under the given files/directories, sorted, deduped."""
    out: List[str] = []
    for p in paths:
        if os.path.isfile(p):
            out.append(p)
            continue
        for root, dirs, files in os.walk(p):
            dirs[:] = sorted(d for d in dirs if d not in _SKIP_DIRS and not d.startswith("."))
            for name in sorted(files):
                if name.endswith(".py"):
                    out.append(os.path.join(root, name))
    seen = set()
    uniq = []
    for f in out:
        norm = f.replace(os.sep, "/")
        if norm not in seen:
            seen.add(norm)
            uniq.append(norm)
    return uniq


def check_module(module: Module, rules: Iterable[Rule]) -> Tuple[List[Finding], int]:
    """All non-suppressed findings for one module (deduped by location+rule),
    plus the count of findings a suppression comment swallowed."""
    raw: List[Finding] = []
    for rule in rules:
        raw.extend(rule.check(module))
    raw = sorted(set(raw))
    smap = suppression_map(module.source)
    findings = [f for f in raw if not is_suppressed(f, smap)]
    return findings, len(raw) - len(findings)


def analyze_source(
    source: str, path: str = "<snippet>", rules: Optional[Iterable[str]] = None
) -> List[Finding]:
    """Findings for one source string placed at a (possibly virtual) path —
    the fixture-test entry point."""
    module = parse_source(source, path)
    findings, _ = check_module(module, all_rules(rules))
    return findings


@dataclasses.dataclass
class Report:
    """Result of one analyzer run over a file set."""

    new: List[Finding]
    grandfathered: List[Finding]
    suppressed: int
    files: int
    parse_errors: List[str]
    snippets: Dict[Finding, str]

    @property
    def exit_code(self) -> int:
        if self.parse_errors:
            return 2
        return 1 if self.new else 0


def run(
    paths: Sequence[str],
    *,
    rules: Optional[Iterable[str]] = None,
    baseline: Optional[Baseline] = None,
) -> Report:
    """Analyze every ``.py`` under ``paths`` and split findings on the baseline."""
    rule_objs = all_rules(rules)
    findings: List[Finding] = []
    snippets: Dict[Finding, str] = {}
    suppressed = 0
    parse_errors: List[str] = []
    files = collect_files(paths)
    for path in files:
        try:
            module = parse_file(path)
        except (SyntaxError, UnicodeDecodeError) as e:
            parse_errors.append(f"{path}: {e}")
            continue
        found, nsup = check_module(module, rule_objs)
        suppressed += nsup
        for f in found:
            snippets[f] = module.snippet(f.line)
        findings.extend(found)
    baseline = baseline or Baseline()
    new, old = baseline.split(findings, snippets)
    return Report(
        new=new,
        grandfathered=old,
        suppressed=suppressed,
        files=len(files),
        parse_errors=parse_errors,
        snippets=snippets,
    )
