"""Pluggable executor backends: where a task's compute actually runs.

The engine (:mod:`repro.runtime.engine`) separates *ordering* from *compute*:
event order comes from the simulated clock of a seeded ``LatencyModel``, while the
task payloads run on one of these backends. Because nothing in the event schedule
depends on where (or when, in wall-clock) the compute happens, the same seed
produces a byte-identical event log and bitwise-identical x̄ on every backend —
the cross-backend determinism contract pinned by ``tests/test_runtime.py``.

Backends:
  * ``inline``  — compute on the master thread, at the moment the arrival event
    pops. Zero concurrency; the reference for the other two.
  * ``thread``  — a ``ThreadPoolExecutor`` (the engine's historical behavior).
    Right choice for jitted JAX payloads: the GIL is released inside XLA.
  * ``process`` — a ``ProcessPoolExecutor`` over *picklable* task specs
    (see :class:`repro.runtime.tasks.SketchSolveCompute`). Worker processes are
    real OS processes, so a task can die (SIGKILL, OOM); the backend detects the
    broken pool, transparently rebuilds it, re-runs innocent casualties, and
    surfaces the genuinely crashing task as :class:`WorkerCrashError` — which the
    engine turns into a ``drop`` event that re-enters the deadline→backoff→retry
    loop with a fresh round-folded key.

:class:`KillSwitch` is the fault injector for the crash path: it wraps a picklable
compute and SIGKILLs its own OS process at chosen (worker, round) coordinates.
It lives here (not in the tests) so spawned workers can unpickle it by a stable
module path.
"""
from __future__ import annotations

import dataclasses
import multiprocessing as mp
import os
import pickle
import signal
from concurrent.futures import ThreadPoolExecutor
from concurrent.futures.process import BrokenProcessPool, ProcessPoolExecutor
from typing import Callable, Optional, Tuple, Union

import numpy as np

ComputeFn = Callable[[int, int], np.ndarray]


class WorkerCrashError(RuntimeError):
    """The OS process running a task died (SIGKILL / OOM) before returning."""


class ExecutorBackend:
    """Minimal executor surface the engine needs. ``submit`` must not block on the
    compute; ``result`` blocks until the handle's value is available (or raises
    :class:`WorkerCrashError` if the worker died)."""

    name: str = "base"

    def submit(self, worker_id: int, round_id: int):
        raise NotImplementedError

    def result(self, handle) -> np.ndarray:
        raise NotImplementedError

    def cancel(self, handle) -> None:  # best-effort; cancelled handles are never read
        pass

    def shutdown(self) -> None:
        pass


class InlineBackend(ExecutorBackend):
    """Run the compute on the master thread when the arrival event pops."""

    name = "inline"

    def __init__(self, compute_fn: ComputeFn, max_workers: int = 1):
        self.compute_fn = compute_fn

    def submit(self, worker_id: int, round_id: int) -> Tuple[int, int]:
        return (int(worker_id), int(round_id))

    def result(self, handle) -> np.ndarray:
        return self.compute_fn(*handle)


class ThreadBackend(ExecutorBackend):
    """Thread-pool compute — overlaps jitted payloads (XLA releases the GIL)."""

    name = "thread"

    def __init__(self, compute_fn: ComputeFn, max_workers: int = 8):
        self.compute_fn = compute_fn
        self._pool = ThreadPoolExecutor(max_workers=max(1, int(max_workers)))

    def submit(self, worker_id: int, round_id: int):
        return self._pool.submit(self.compute_fn, int(worker_id), int(round_id))

    def result(self, handle) -> np.ndarray:
        return handle.result()

    def cancel(self, handle) -> None:
        handle.cancel()

    def shutdown(self) -> None:
        self._pool.shutdown(wait=False, cancel_futures=True)


# ------------------------------------------------------------------ process backend

# Worker-process global: the unpickled compute, installed once per process by the
# pool initializer so task submissions ship only (worker_id, round_id) — the data
# (A, b, key) crosses the process boundary exactly once.
_PROCESS_COMPUTE: Optional[ComputeFn] = None


def _process_worker_init(payload: bytes) -> None:
    global _PROCESS_COMPUTE
    _PROCESS_COMPUTE = pickle.loads(payload)


def _process_worker_run(worker_id: int, round_id: int):
    return _PROCESS_COMPUTE(worker_id, round_id)


@dataclasses.dataclass
class _ProcessHandle:
    worker_id: int
    round_id: int
    future: object


class ProcessBackend(ExecutorBackend):
    """Multi-process compute over a picklable task spec, with crash detection.

    A SIGKILLed worker marks the whole ``ProcessPoolExecutor`` broken: every
    unresolved future raises ``BrokenProcessPool``, innocent or not. ``result``
    therefore rebuilds the pool and resubmits the popped handle once — a pure
    compute re-runs to the identical value, so innocent casualties stay invisible
    in the event log — and only a handle that breaks the pool *twice* is reported
    as :class:`WorkerCrashError` (the engine's ``drop`` path). The pool is always
    left healthy afterwards so the retry with a fresh round key can run.

    ``start_method`` defaults to ``spawn``: forking after the parent initialized
    an XLA client is unsafe, and spawned children re-import JAX cleanly (the
    dominant cost — keep ``max_workers`` small).
    """

    name = "process"

    def __init__(
        self,
        compute_fn: ComputeFn,
        max_workers: int = 2,
        start_method: str = "spawn",
    ):
        # Pickling up front both validates the task spec and freezes the payload
        # the initializer ships to every worker process.
        self._payload = pickle.dumps(compute_fn)
        self._max_workers = max(1, int(max_workers))
        self._ctx = mp.get_context(start_method)
        self._pool: Optional[ProcessPoolExecutor] = None

    def _make_pool(self) -> ProcessPoolExecutor:
        return ProcessPoolExecutor(
            max_workers=self._max_workers,
            mp_context=self._ctx,
            initializer=_process_worker_init,
            initargs=(self._payload,),
        )

    def _rebuild_pool(self) -> None:
        if self._pool is not None:
            self._pool.shutdown(wait=False, cancel_futures=True)
        self._pool = self._make_pool()

    def _submit_raw(self, worker_id: int, round_id: int):
        if self._pool is None:
            self._pool = self._make_pool()
        try:
            return self._pool.submit(_process_worker_run, int(worker_id), int(round_id))
        except BrokenProcessPool:
            # A crash elsewhere already poisoned the pool; this task is innocent.
            self._rebuild_pool()
            return self._pool.submit(_process_worker_run, int(worker_id), int(round_id))

    def submit(self, worker_id: int, round_id: int) -> _ProcessHandle:
        return _ProcessHandle(int(worker_id), int(round_id), self._submit_raw(worker_id, round_id))

    def result(self, handle: _ProcessHandle) -> np.ndarray:
        for resubmitted in (False, True):
            try:
                return handle.future.result()
            except BrokenProcessPool:
                self._rebuild_pool()
                if not resubmitted:
                    handle.future = self._pool.submit(
                        _process_worker_run, handle.worker_id, handle.round_id
                    )
        raise WorkerCrashError(
            f"worker process died running task (worker={handle.worker_id}, "
            f"round={handle.round_id}) — killed twice in a row, reporting a drop"
        )

    def cancel(self, handle: _ProcessHandle) -> None:
        handle.future.cancel()

    def shutdown(self) -> None:
        if self._pool is not None:
            self._pool.shutdown(wait=False, cancel_futures=True)
            self._pool = None


# ---------------------------------------------------------------------- fault injection


@dataclasses.dataclass
class KillSwitch:
    """Chaos-monkey wrapper for fault-injection tests: SIGKILL the executing OS
    process when the task coordinate matches. Only meaningful on the ``process``
    backend — on ``inline``/``thread`` it would kill the master itself, so
    ``__call__`` refuses unless the current pid differs from ``master_pid``.
    """

    inner: ComputeFn
    kill_coords: Tuple[Tuple[int, int], ...] = ()
    master_pid: int = dataclasses.field(default_factory=os.getpid)

    def __call__(self, worker_id: int, round_id: int) -> np.ndarray:
        if (int(worker_id), int(round_id)) in {tuple(c) for c in self.kill_coords}:
            if os.getpid() == self.master_pid:
                raise RuntimeError(
                    "KillSwitch fired on the master process — use the 'process' backend"
                )
            os.kill(os.getpid(), signal.SIGKILL)
        return self.inner(worker_id, round_id)


# ----------------------------------------------------------------------------- factory

BACKENDS = {
    "inline": InlineBackend,
    "thread": ThreadBackend,
    "process": ProcessBackend,
}


def make_backend(
    kind: Union[str, ExecutorBackend],
    compute_fn: ComputeFn,
    *,
    max_workers: int = 8,
) -> ExecutorBackend:
    """Resolve a backend name (or pass through an instance) for one engine run."""
    if isinstance(kind, ExecutorBackend):
        return kind
    try:
        cls = BACKENDS[kind]
    except KeyError:
        raise ValueError(f"unknown backend {kind!r}; expected one of {sorted(BACKENDS)}")
    return cls(compute_fn, max_workers=max_workers)
