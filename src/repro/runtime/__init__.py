"""`repro.runtime` — asynchronous serverless execution engine.

The paper's deployment model as a first-class subsystem: an event-driven master
that invokes stateless sketch-solve workers, folds results into a running average
as they arrive (Algorithm 1 with the realized q′), retries blown deadlines and
crashed workers with fresh i.i.d. sketches, stops early when the estimate is
accurate enough, and logs every transition as structured telemetry.

    from repro import runtime as rt

    res = rt.serverless_sketch_solve(
        spec, key, A, b, q=32,
        latency=rt.HeavyTailLatency(scale_s=0.5, alpha=1.5, seed=0),
        config=rt.RuntimeConfig(deadline_s=1.0, max_retries=2, target_error=1e-2),
        error_fn="probe",
        backend="process",                  # or "inline" / "thread" (default)
        deadline=rt.AdaptiveDeadline(),     # rolling-p95 deadlines, else static
    )
    res.xbar                # the running average at stop time
    res.events.to_jsonl(p)  # deterministic replay log — identical on every backend
    res.summary()           # p50/p95, retries, timeouts, drops, effective q', ...
"""
from repro.runtime.backends import (
    BACKENDS,
    ExecutorBackend,
    InlineBackend,
    KillSwitch,
    ProcessBackend,
    ThreadBackend,
    WorkerCrashError,
    make_backend,
)
from repro.runtime.engine import (
    AdaptiveDeadline,
    DeadlinePolicy,
    DeadlineTracker,
    RuntimeConfig,
    RuntimeResult,
    ServerlessEngine,
    StaticDeadline,
    TaskQueue,
    resolve_deadline_policy,
)
from repro.runtime.latency import (
    ConstantLatency,
    DriftLatency,
    DropLatency,
    HeavyTailLatency,
    LatencyModel,
    LognormalLatency,
)
from repro.runtime.tasks import (
    LeastNormCompute,
    SketchSolveCompute,
    make_least_norm_compute,
    make_sketch_solve_compute,
    probe_error_fn,
    resolve_error_fn,
    serverless_sketch_solve,
    subsample_probe,
    theory_error_fn,
)
from repro.runtime.telemetry import Event, EventLog

__all__ = [
    "RuntimeConfig",
    "RuntimeResult",
    "ServerlessEngine",
    "TaskQueue",
    "DeadlinePolicy",
    "DeadlineTracker",
    "StaticDeadline",
    "AdaptiveDeadline",
    "resolve_deadline_policy",
    "ExecutorBackend",
    "InlineBackend",
    "ThreadBackend",
    "ProcessBackend",
    "KillSwitch",
    "WorkerCrashError",
    "make_backend",
    "BACKENDS",
    "LatencyModel",
    "ConstantLatency",
    "LognormalLatency",
    "HeavyTailLatency",
    "DriftLatency",
    "DropLatency",
    "Event",
    "EventLog",
    "SketchSolveCompute",
    "LeastNormCompute",
    "make_sketch_solve_compute",
    "make_least_norm_compute",
    "serverless_sketch_solve",
    "theory_error_fn",
    "probe_error_fn",
    "resolve_error_fn",
    "subsample_probe",
]
