"""Structured task telemetry: the event log is the runtime's source of truth.

Every state transition in the engine emits one :class:`Event`; the ordered list
*is* the execution (simulated clock, no wall-clock fields), so

  * replay is checkable — same seed ⇒ byte-identical JSONL,
  * the error-vs-wallclock trace of the paper's Fig. 1 falls out of the ``arrive``
    events' ``error`` extras,
  * the summary report subsumes ``HeartbeatMonitor.report()`` (same keys plus the
    p50 / retry / timeout extensions) by replaying arrivals into a monitor.

Event kinds: ``dispatch`` | ``arrive`` | ``timeout`` | ``drop`` | ``retry`` |
``cancel`` | ``stop`` — ``drop`` is the process backend's crash signal (a worker
OS process died mid-task); it re-enters the same retry loop as ``timeout``.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class Event:
    seq: int            # global order (ties in t broken by dispatch order)
    t: float            # simulated seconds since the master started the job
    kind: str
    task_id: int        # stable id of the logical task (survives retries)
    worker_id: int
    round_id: int       # the key-fold round — retries get *fresh* rounds
    attempt: int
    extra: Dict[str, float] = dataclasses.field(default_factory=dict)

    def to_json(self) -> str:
        rec = {
            "seq": self.seq,
            "t": round(self.t, 9),
            "kind": self.kind,
            "task_id": self.task_id,
            "worker_id": self.worker_id,
            "round_id": self.round_id,
            "attempt": self.attempt,
        }
        rec.update({k: self.extra[k] for k in sorted(self.extra)})
        return json.dumps(rec)


class EventLog:
    """Append-only, simulated-time-ordered record of one engine run."""

    def __init__(self):
        self.events: List[Event] = []

    def emit(self, t, kind, task_id, worker_id, round_id, attempt, **extra) -> Event:
        ev = Event(
            seq=len(self.events), t=float(t), kind=kind, task_id=int(task_id),
            worker_id=int(worker_id), round_id=int(round_id), attempt=int(attempt),
            extra={k: float(v) for k, v in extra.items() if v is not None},
        )
        self.events.append(ev)
        return ev

    def __len__(self) -> int:
        return len(self.events)

    def __iter__(self):
        return iter(self.events)

    def lines(self) -> List[str]:
        return [ev.to_json() for ev in self.events]

    def to_jsonl(self, path: str) -> str:
        with open(path, "w") as f:
            for line in self.lines():
                f.write(line + "\n")
        return path

    # ------------------------------------------------------------------ queries

    def counts(self) -> Dict[str, int]:
        c: Dict[str, int] = {}
        for ev in self.events:
            c[ev.kind] = c.get(ev.kind, 0) + 1
        return c

    def arrivals(self) -> List[Event]:
        return [ev for ev in self.events if ev.kind == "arrive"]

    def error_trace(self) -> List[Tuple[float, int, float]]:
        """(sim_time, running_count, running_error) at every arrival that carried an
        error estimate — the error-vs-wallclock curve of the paper's Fig. 1."""
        out = []
        for ev in self.arrivals():
            if "error" in ev.extra:
                out.append((ev.t, int(ev.extra.get("count", 0)), ev.extra["error"]))
        return out

    def heartbeat_report(self, q: int, deadline: float) -> Dict[str, float]:
        """Replay this log into a ``HeartbeatMonitor`` and emit its (extended) report.

        Attempt-0 latencies form the wave the monitor scores against ``deadline``
        (hard drops enter as +inf runtimes, i.e. missed); retry/timeout events feed
        the monitor's counters, and worker crashes (``drop``) count as timeouts —
        the monitor has no finer-grained bucket for a dead worker. The result is a strict superset of the pre-runtime
        ``HeartbeatMonitor.report()`` schema.
        """
        import numpy as np

        from repro.distributed.fault_tolerance import HeartbeatMonitor

        mon = HeartbeatMonitor(q=q, deadline=deadline)
        wave = np.full((q,), np.inf)
        for ev in self.events:
            if ev.attempt == 0 and ev.kind in ("arrive", "timeout") and 0 <= ev.worker_id < q:
                lat = ev.extra.get("latency_s", np.inf)
                wave[ev.worker_id] = min(wave[ev.worker_id], lat)
            if ev.kind in ("timeout", "drop"):
                mon.record_timeout()
            if ev.kind == "retry":
                mon.record_retry()
        mon.record_step(wave)
        return mon.report()

    def summary(self, *, q: Optional[int] = None, deadline: Optional[float] = None) -> Dict:
        """One dict for JSON reports: event counts, latency percentiles over all
        arrivals, effective q' (results actually averaged), sim makespan, and —
        when (q, deadline) are given — the embedded heartbeat report."""
        import numpy as np

        counts = self.counts()
        lats = [ev.extra["latency_s"] for ev in self.arrivals() if "latency_s" in ev.extra]
        out: Dict = {
            "events": len(self.events),
            "counts": counts,
            "effective_q": counts.get("arrive", 0),
            "retries": counts.get("retry", 0),
            "timeouts": counts.get("timeout", 0),
            "drops": counts.get("drop", 0),
            "cancelled": counts.get("cancel", 0),
            "sim_makespan_s": self.events[-1].t if self.events else 0.0,
        }
        if lats:
            out["p50_latency_s"] = float(np.quantile(lats, 0.50))
            out["p95_latency_s"] = float(np.quantile(lats, 0.95))
            out["mean_latency_s"] = float(np.mean(lats))
        if q is not None and deadline is not None:
            out["heartbeat"] = self.heartbeat_report(q, deadline)
        return out
