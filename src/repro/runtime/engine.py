"""Asynchronous serverless execution engine (the paper's master, made explicit).

The paper's Algorithm 1 is usually summarized as "average q sketched solutions",
but its deployment is an *event loop*: the master invokes q stateless lambdas,
results trickle in under a random latency distribution, the master folds each one
into a running average the moment it arrives, re-invokes workers that blew the
deadline, and stops as soon as the estimate is good enough — it never waits for
the stragglers it can do without. This module is that loop, built to be both

  * **really parallel** — each task's compute (a jitted sketch-and-solve closure)
    runs on a pluggable :mod:`~repro.runtime.backends` executor (``inline``,
    ``thread``, or a real multi-process pool), and
  * **exactly replayable** — *ordering* comes only from the simulated clock of a
    seeded :class:`~repro.runtime.latency.LatencyModel` plus a deterministic
    dispatch-order tiebreak, never from thread or process scheduling. Same seed ⇒
    identical event log (byte-for-byte JSONL) and bitwise-identical x̄,
    *regardless of backend or pool width*.

Pieces:
  * :class:`TaskQueue`   — the priority queue of future events (arrivals/timeouts),
    keyed by (sim_time, seq) so ties resolve deterministically.
  * :class:`RuntimeConfig` — deadline, retry/backoff, early-stop target, backend.
  * :class:`DeadlinePolicy` — per-dispatch deadlines: :class:`StaticDeadline`
    (the historical fixed cutoff) or :class:`AdaptiveDeadline` (rolling-p95 of
    the telemetry stream, clamped, with a warm-up default before enough samples).
  * :class:`ServerlessEngine.run` — dispatch → {arrive | timeout → backoff+retry |
    crash → drop → backoff+retry} with a Welford running mean (partial averages
    exact at every event), early stopping on a pluggable error estimate, and
    cancellation of in-flight work.

Retries are *new i.i.d. sketches*, never replays: each resubmission — whether the
deadline was blown or the worker process was killed mid-task — draws a fresh
``round_id`` from a monotone counter, and the worker key is
``prng.worker_key(base_key, worker_id, round_id)`` — the same key a synchronous
mesh worker with that (worker, round) coordinate would derive, which is what makes
the runtime-vs-``distributed_sketch_solve`` equivalence testable.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from collections import deque
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.runtime.backends import ExecutorBackend, WorkerCrashError, make_backend
from repro.runtime.latency import LatencyModel
from repro.runtime.telemetry import EventLog


@dataclasses.dataclass(frozen=True)
class RuntimeConfig:
    """Knobs of the master loop.

    deadline_s:      per-invocation deadline; a task that would finish later times
                     out (its compute is never scheduled — the lambda is abandoned).
                     Overridden per dispatch when a :class:`DeadlinePolicy` is
                     passed to the engine.
    max_retries:     resubmissions per logical task after its first timeout/crash.
    backoff_base_s:  wait before the first retry; grows by ``backoff_factor``.
    target_error:    early-stop threshold for the run's error estimate (None = run
                     every task to completion).
    min_results:     never early-stop on fewer than this many folded results.
    max_threads:     pool width for the actual compute (threads or processes).
    backend:         default executor backend — ``"inline"`` | ``"thread"`` |
                     ``"process"`` (see :mod:`repro.runtime.backends`).
    """

    deadline_s: float = 1.0
    max_retries: int = 2
    backoff_base_s: float = 0.05
    backoff_factor: float = 2.0
    target_error: Optional[float] = None
    min_results: int = 1
    max_threads: int = 8
    backend: str = "thread"


class TaskQueue:
    """Deterministic future-event queue: pops in (sim_time, push_order) order."""

    def __init__(self):
        self._heap: List[Tuple[float, int, dict]] = []
        self._pushes = 0

    def push(self, t: float, item: dict) -> None:
        heapq.heappush(self._heap, (float(t), self._pushes, item))
        self._pushes += 1

    def pop(self) -> Tuple[float, dict]:
        t, _, item = heapq.heappop(self._heap)
        return t, item

    def drain(self) -> List[Tuple[float, dict]]:
        out = []
        while self._heap:
            out.append(self.pop())
        return out

    def __len__(self) -> int:
        return len(self._heap)


# ------------------------------------------------------------------ deadline policies


class DeadlineTracker:
    """Mutable per-run state of a :class:`DeadlinePolicy`. ``current()`` is read at
    every dispatch; ``observe``/``observe_timeout`` are fed from the event stream
    in simulated-clock order, so the deadline sequence is replay-deterministic."""

    def observe(self, latency_s: float) -> None:
        pass

    def observe_timeout(self, deadline_s: float) -> None:
        pass

    def current(self) -> float:
        raise NotImplementedError


class DeadlinePolicy:
    """Immutable spec; ``start()`` yields a fresh tracker for one engine run."""

    def start(self) -> DeadlineTracker:
        raise NotImplementedError


class _StaticTracker(DeadlineTracker):
    def __init__(self, deadline_s: float):
        self._deadline_s = float(deadline_s)

    def current(self) -> float:
        return self._deadline_s


@dataclasses.dataclass(frozen=True)
class StaticDeadline(DeadlinePolicy):
    """The historical behavior: one fixed cutoff for every dispatch."""

    deadline_s: float = 1.0

    def start(self) -> DeadlineTracker:
        return _StaticTracker(self.deadline_s)


class _AdaptiveTracker(DeadlineTracker):
    def __init__(self, policy: "AdaptiveDeadline"):
        self._p = policy
        self._samples: deque = deque(maxlen=policy.window)

    def observe(self, latency_s: float) -> None:
        if math.isfinite(latency_s):
            self._samples.append(float(latency_s))

    def observe_timeout(self, deadline_s: float) -> None:
        # A timeout is a censored observation: the true latency is only known to
        # exceed the deadline. Recording deadline × timeout_factor lets repeated
        # timeouts push the estimate *up* instead of anchoring it at the cutoff.
        if math.isfinite(deadline_s):
            self._samples.append(float(deadline_s) * self._p.timeout_factor)

    def current(self) -> float:
        p = self._p
        if len(self._samples) < p.min_samples:
            raw = p.warmup_s
        else:
            raw = float(np.quantile(np.asarray(self._samples), p.quantile)) * p.margin
        return min(max(raw, p.min_s), p.max_s)


@dataclasses.dataclass(frozen=True)
class AdaptiveDeadline(DeadlinePolicy):
    """Online deadlines from the telemetry stream: rolling p-quantile (default p95)
    of the last ``window`` observed task latencies, scaled by ``margin`` and
    clamped to ``[min_s, max_s]``. Before ``min_samples`` observations the
    (clamped) ``warmup_s`` default applies — the whole initial wave dispatches at
    t=0, so adaptation kicks in on retries and later rounds, exactly where a
    mis-set static deadline burns its retry budget.

    The deadline is monotone in the observed latencies and always within the
    clamp — pinned by a property test in ``tests/test_properties.py``.
    """

    warmup_s: float = 1.0
    quantile: float = 0.95
    margin: float = 1.25
    min_samples: int = 5
    window: int = 64
    min_s: float = 1e-3
    max_s: float = 120.0
    timeout_factor: float = 1.5

    def start(self) -> DeadlineTracker:
        return _AdaptiveTracker(self)


def resolve_deadline_policy(
    deadline: Union[None, float, DeadlinePolicy], config: RuntimeConfig
) -> DeadlinePolicy:
    """None → the config's static deadline; a float → a static policy; a policy →
    itself. Keeps every pre-policy call site working unchanged."""
    if deadline is None:
        return StaticDeadline(config.deadline_s)
    if isinstance(deadline, DeadlinePolicy):
        return deadline
    return StaticDeadline(float(deadline))


@dataclasses.dataclass
class RuntimeResult:
    """What one engine run produced (x̄ plus its full provenance)."""

    xbar: np.ndarray                    # running average over everything that arrived
    count: int                          # realized q' — results actually folded in
    submitted: int                      # logical tasks in the initial wave
    dispatched: int                     # invocations incl. retries
    arrived: List[Tuple[int, int, int]]  # (worker_id, round_id, attempt), arrival order
    stopped_early: bool
    final_error: Optional[float]        # last error estimate (None if no estimator)
    events: EventLog

    @property
    def realized_mask(self) -> np.ndarray:
        """(q,) float mask over the initial wave: 1 where worker w's *attempt-0*
        task arrived (and was folded in before any early stop). Feeding this to
        ``distributed_sketch_solve(..., straggler_mask=...)`` reproduces x̄ exactly
        when no retries arrived (retried tasks carry fresh rounds the synchronous
        call knows nothing about)."""
        mask = np.zeros((self.submitted,), np.float32)
        for w, _, attempt in self.arrived:
            if attempt == 0 and 0 <= w < self.submitted:
                mask[w] = 1.0
        return mask

    def summary(self, *, deadline: Optional[float] = None) -> Dict:
        s = self.events.summary(q=self.submitted, deadline=deadline)
        s.update(
            count=self.count,
            submitted=self.submitted,
            dispatched=self.dispatched,
            stopped_early=self.stopped_early,
            final_error=self.final_error,
        )
        return s


class ServerlessEngine:
    """The master loop: dispatch, fold arrivals, retry timeouts/crashes, stop when done.

    ``compute_fn(worker_id, round_id) -> np.ndarray`` is the worker payload — see
    :mod:`repro.runtime.tasks` for the sketch-solve builders (picklable, as the
    ``process`` backend requires). It must be a pure function of its arguments
    (workers are stateless lambdas); it runs on the executor backend while the
    event loop orders everything by simulated time.

    ``backend``: a name (``"inline"``/``"thread"``/``"process"``), an
    :class:`~repro.runtime.backends.ExecutorBackend` instance (reused across runs,
    never shut down by the engine), or None → ``config.backend``.
    ``deadline``: a :class:`DeadlinePolicy`, a float, or None → the config's
    static ``deadline_s``.
    """

    def __init__(
        self,
        compute_fn: Callable[[int, int], np.ndarray],
        latency: LatencyModel,
        config: Optional[RuntimeConfig] = None,
        *,
        backend: Union[None, str, ExecutorBackend] = None,
        deadline: Union[None, float, DeadlinePolicy] = None,
    ):
        self.compute_fn = compute_fn
        self.latency = latency
        self.config = config or RuntimeConfig()
        self.backend = backend
        self.deadline = deadline

    # ------------------------------------------------------------------ run

    def run(
        self,
        q: Optional[int] = None,
        *,
        tasks: Optional[Sequence[Tuple[int, int]]] = None,
        error_fn: Optional[Callable[[np.ndarray, int], float]] = None,
    ) -> RuntimeResult:
        """Execute one job: the initial wave is ``tasks`` ([(worker_id, round_id)])
        or, when only ``q`` is given, [(0,0) … (q-1,0)] — one task per worker,
        round 0, exactly Algorithm 1's single wave.

        ``error_fn(xbar, count)`` is evaluated at every arrival; its value is logged
        on the event (the error-vs-wallclock trace) and compared against
        ``config.target_error`` for early stopping.
        """
        cfg = self.config
        if tasks is None:
            if q is None:
                raise ValueError("pass q or an explicit task list")
            tasks = [(w, 0) for w in range(q)]
        tasks = [(int(w), int(r)) for w, r in tasks]
        next_round = max((r for _, r in tasks), default=-1) + 1

        tracker = resolve_deadline_policy(self.deadline, cfg).start()
        backend_owned = not isinstance(self.backend, ExecutorBackend)
        backend = make_backend(
            self.backend if self.backend is not None else cfg.backend,
            self.compute_fn,
            max_workers=cfg.max_threads,
        )

        queue = TaskQueue()
        log = EventLog()
        mean: Optional[np.ndarray] = None
        count = 0
        dispatched = 0
        arrived: List[Tuple[int, int, int]] = []
        final_error: Optional[float] = None
        stopped = False

        def dispatch(t: float, task_id: int, w: int, r: int, attempt: int) -> None:
            nonlocal dispatched
            dispatched += 1
            dl = tracker.current()
            lat = self.latency.sample(w, r, attempt)
            log.emit(t, "dispatch", task_id, w, r, attempt, latency_s=lat,
                     deadline_s=None if math.isinf(dl) else dl)
            if lat <= dl:
                handle = backend.submit(w, r)
                queue.push(
                    t + lat,
                    {"kind": "arrive", "task_id": task_id, "w": w, "r": r,
                     "attempt": attempt, "latency_s": lat, "deadline_s": dl,
                     "handle": handle},
                )
            else:
                # The result would miss the deadline — the master abandons the
                # invocation (never schedules its compute) and hears the timeout.
                queue.push(
                    t + dl,
                    {"kind": "timeout", "task_id": task_id, "w": w, "r": r,
                     "attempt": attempt, "latency_s": lat, "deadline_s": dl},
                )

        def retry(t: float, task_id: int, w: int, attempt: int) -> None:
            nonlocal next_round
            if attempt < cfg.max_retries:
                delay = cfg.backoff_base_s * cfg.backoff_factor ** attempt
                fresh = next_round
                next_round += 1
                log.emit(t, "retry", task_id, w, fresh, attempt + 1, backoff_s=delay)
                dispatch(t + delay, task_id, w, fresh, attempt + 1)

        try:
            for task_id, (w, r) in enumerate(tasks):
                dispatch(0.0, task_id, w, r, attempt=0)

            while len(queue):
                t, item = queue.pop()
                task_id, w, r, attempt = item["task_id"], item["w"], item["r"], item["attempt"]

                if item["kind"] == "arrive":
                    try:
                        x = np.asarray(backend.result(item["handle"]), dtype=np.float64)
                    except WorkerCrashError:
                        # The OS process running this task died mid-compute. The
                        # master hears silence where a result was due: a drop,
                        # re-entering the same backoff→retry loop as a timeout
                        # (fresh round-folded key, new i.i.d. sketch).
                        log.emit(t, "drop", task_id, w, r, attempt,
                                 latency_s=item["latency_s"])
                        retry(t, task_id, w, attempt)
                        continue
                    tracker.observe(item["latency_s"])
                    count += 1
                    mean = x.copy() if mean is None else mean + (x - mean) / count
                    arrived.append((w, r, attempt))
                    err = None
                    if error_fn is not None:
                        err = float(error_fn(mean, count))
                        final_error = err
                    log.emit(t, "arrive", task_id, w, r, attempt,
                             latency_s=item["latency_s"], count=count, error=err)
                    if (
                        cfg.target_error is not None
                        and err is not None
                        and err <= cfg.target_error
                        and count >= cfg.min_results
                    ):
                        log.emit(t, "stop", task_id, w, r, attempt,
                                 count=count, error=err)
                        stopped = True
                        for tc, pending in queue.drain():
                            log.emit(
                                tc, "cancel", pending["task_id"], pending["w"],
                                pending["r"], pending["attempt"],
                            )
                            handle = pending.get("handle")
                            if handle is not None:
                                backend.cancel(handle)
                        break

                elif item["kind"] == "timeout":
                    tracker.observe_timeout(item["deadline_s"])
                    log.emit(t, "timeout", task_id, w, r, attempt,
                             latency_s=item["latency_s"])
                    retry(t, task_id, w, attempt)
        finally:
            if backend_owned:
                backend.shutdown()

        if mean is None:
            raise RuntimeError(
                "no worker result ever arrived (all tasks dropped or timed out "
                f"after {cfg.max_retries} retries) — x̄ is undefined; loosen the "
                "deadline, raise max_retries, or use a lighter LatencyModel"
            )
        return RuntimeResult(
            xbar=mean, count=count, submitted=len(tasks), dispatched=dispatched,
            arrived=arrived, stopped_early=stopped, final_error=final_error,
            events=log,
        )
