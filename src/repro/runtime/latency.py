"""Seeded latency models: the serverless runtime distribution, injected not measured.

The paper's experiments run on AWS Lambda, where worker runtimes are random and
heavy-tailed — the whole point of Algorithm 1 is that the master does not wait for
the tail. To study that regime deterministically, the runtime engine never *measures*
wall-clock; it *draws* each task's runtime from a ``LatencyModel``.

Determinism contract: ``sample(worker_id, round_id, attempt)`` is a pure function of
``(seed, worker_id, round_id, attempt)`` — counter-based Philox, no global state — so
the same seed replays the identical event schedule no matter how the thread pool
interleaves the actual compute. ``math.inf`` means the invocation never returns
(a hard drop: the lambda was killed).

Models mirror ``distributed.fault_tolerance.StragglerPolicy`` (which adapts onto
these via ``StragglerPolicy.to_latency_model``):

  * ``LognormalLatency`` — the paper's observed Lambda profile (Fig. 1 captions).
  * ``HeavyTailLatency`` — Pareto tail; stragglers arbitrarily late, mean may not
    even exist for ``alpha <= 1``. The regime where ignoring the tail pays most.
  * ``DropLatency``      — wraps another model with hard failures.
  * ``DriftLatency``     — lognormal whose median drifts geometrically with the
    round id (cold starts, queue buildup): the regime adaptive deadlines exist for.
  * ``ConstantLatency``  — degenerate model for tests and synchronous baselines.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np


def _rng(seed: int, salt: int, worker_id: int, round_id: int, attempt: int) -> np.random.Generator:
    """Counter-based generator: a pure function of the full task coordinate."""
    ss = np.random.SeedSequence([int(seed), int(salt), int(worker_id), int(round_id), int(attempt)])
    return np.random.Generator(np.random.Philox(ss))


@dataclasses.dataclass(frozen=True)
class LatencyModel:
    """Base class. Subclasses draw from ``_rng`` so samples are replayable."""

    seed: int = 0

    _SALT = 0x5E12  # distinguishes the latency stream from any other Philox user

    def sample(self, worker_id: int, round_id: int = 0, attempt: int = 0) -> float:
        """Simulated runtime in seconds for one invocation; ``math.inf`` = never."""
        raise NotImplementedError

    def sample_wave(self, q: int, round_id: int = 0, attempt: int = 0) -> np.ndarray:
        """(q,) runtimes for one wave of workers."""
        return np.array([self.sample(w, round_id, attempt) for w in range(q)])

    def mask_for_round(self, q: int, deadline: float, round_id: int = 0) -> np.ndarray:
        """0/1 float mask of workers that would beat ``deadline`` in this round."""
        return (self.sample_wave(q, round_id) <= deadline).astype(np.float32)


@dataclasses.dataclass(frozen=True)
class ConstantLatency(LatencyModel):
    value_s: float = 1.0

    def sample(self, worker_id: int, round_id: int = 0, attempt: int = 0) -> float:
        return float(self.value_s)


@dataclasses.dataclass(frozen=True)
class LognormalLatency(LatencyModel):
    """runtime = mean_s · exp(sigma·Z), Z ~ N(0,1) — median ``mean_s``."""

    mean_s: float = 1.0
    sigma: float = 0.35

    def sample(self, worker_id: int, round_id: int = 0, attempt: int = 0) -> float:
        g = _rng(self.seed, self._SALT, worker_id, round_id, attempt)
        return float(self.mean_s * math.exp(self.sigma * g.standard_normal()))

    def quantile(self, p: float) -> float:
        """Closed-form latency quantile — e.g. a deadline at the p-th percentile."""
        from jax.scipy.special import ndtri  # inverse normal CDF

        return float(self.mean_s * math.exp(self.sigma * float(ndtri(p))))


@dataclasses.dataclass(frozen=True)
class HeavyTailLatency(LatencyModel):
    """runtime = scale_s · (1 + Pareto(alpha)): support [scale_s, ∞), power-law tail."""

    scale_s: float = 1.0
    alpha: float = 1.5

    def sample(self, worker_id: int, round_id: int = 0, attempt: int = 0) -> float:
        g = _rng(self.seed, self._SALT, worker_id, round_id, attempt)
        return float(self.scale_s * (1.0 + g.pareto(self.alpha)))


@dataclasses.dataclass(frozen=True)
class DriftLatency(LatencyModel):
    """Non-stationary lognormal: median ``mean_s · growth^round_id``. With
    ``growth > 1`` later rounds (and every retry, which always carries a fresh,
    larger round id) run slower — a static deadline tuned on round 0 burns its
    whole retry budget, while an :class:`~repro.runtime.engine.AdaptiveDeadline`
    tracks the drift through the telemetry stream."""

    mean_s: float = 1.0
    sigma: float = 0.35
    growth: float = 1.3

    def sample(self, worker_id: int, round_id: int = 0, attempt: int = 0) -> float:
        g = _rng(self.seed, self._SALT, worker_id, round_id, attempt)
        median = self.mean_s * self.growth ** round_id
        return float(median * math.exp(self.sigma * g.standard_normal()))


@dataclasses.dataclass(frozen=True)
class DropLatency(LatencyModel):
    """Hard failures layered on any base model: with prob ``drop_prob`` the task
    never returns (``inf``); otherwise the inner model's draw. The drop coin and the
    inner draw use distinct salts, so wrapping does not perturb the inner stream."""

    inner: LatencyModel = dataclasses.field(default_factory=LognormalLatency)
    drop_prob: float = 0.0

    _DROP_SALT = 0xD409

    def sample(self, worker_id: int, round_id: int = 0, attempt: int = 0) -> float:
        g = _rng(self.seed, self._DROP_SALT, worker_id, round_id, attempt)
        if g.random() < self.drop_prob:
            return math.inf
        return self.inner.sample(worker_id, round_id, attempt)
