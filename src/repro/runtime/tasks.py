"""Worker payloads and error estimators for the runtime engine.

A *task* is one serverless invocation: derive the (worker, round) key, sketch,
solve, return x̂_k. The builders here produce ``compute_fn(worker_id, round_id)``
callables over one jitted kernel, reusing the exact solver stack of the
synchronous path — ``solve.sketch_and_solve`` with the fused single-pass
sketch→Gram pipeline by default — and the exact key schedule
``prng.worker_key(base_key, w, round)`` of the ``shard_map`` workers, so an async
run and a mesh run with the same realized worker set agree to float tolerance.

The payloads are *picklable task specs* (plain classes over numpy state, the jit
cache rebuilt lazily per process), which is what lets the ``process`` executor
backend ship one payload to each worker process and submit bare
``(worker_id, round_id)`` coordinates afterwards. On the thread/inline backends
they behave exactly like the closures they replaced — the jitted solve is
compiled once per payload and shared by every thread.

Early-stop estimators (for ``RuntimeConfig.target_error``):

  * :func:`theory_error_fn` — Theorem 1's closed form d/(q′(m−d−1)): predicted
    relative error after q′ Gaussian results (a heuristic proxy for other kinds).
  * :func:`probe_error_fn` — a held-out residual probe: relative excess cost of x̄
    on (A_p, b_p) against the probe's own optimum, no theory assumptions.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sketches as sk, solve, theory
from repro.runtime.backends import ExecutorBackend
from repro.runtime.engine import (
    DeadlinePolicy,
    RuntimeConfig,
    RuntimeResult,
    ServerlessEngine,
)
from repro.runtime.latency import LatencyModel
from repro.utils import prng


def _key_data(key) -> np.ndarray:
    """Raw uint32 words of a jax PRNG key (legacy or typed) — picklable."""
    try:
        return np.asarray(key)
    except TypeError:  # new-style typed key array
        return np.asarray(jax.random.key_data(key))


class _PicklableCompute:
    """Base for process-shippable payloads: numpy state + a lazily built jit."""

    def __init__(self, spec: sk.SketchSpec, base_key, A, b):
        self.spec = spec
        self.base_key = _key_data(base_key)
        self.A = np.asarray(A)
        self.b = np.asarray(b)
        self._fn = None

    def _build(self) -> Callable:
        raise NotImplementedError

    def __call__(self, worker_id: int, round_id: int) -> np.ndarray:
        if self._fn is None:
            self._fn = self._build()
        wkey = prng.worker_key(jnp.asarray(self.base_key), worker_id, round_id)
        return np.asarray(self._fn(wkey))

    def __getstate__(self):
        state = dict(self.__dict__)
        state["_fn"] = None  # jit caches never cross process boundaries
        return state


class SketchSolveCompute(_PicklableCompute):
    """One Algorithm-1 worker as a task spec: (worker, round) ↦ x̂ ∈ R^d."""

    def __init__(self, spec, base_key, A, b, *, reg: float = 0.0, method: str = "fused"):
        super().__init__(spec, base_key, A, b)
        self.reg = float(reg)
        self.method = str(method)

    def _build(self):
        A, b = jnp.asarray(self.A), jnp.asarray(self.b)
        spec, reg, method = self.spec, self.reg, self.method
        return jax.jit(lambda wkey: solve.sketch_and_solve(spec, wkey, A, b, reg=reg, method=method))


class LeastNormCompute(_PicklableCompute):
    """§V right-sketch worker (n < d) as a task spec."""

    def _build(self):
        A, b, spec = jnp.asarray(self.A), jnp.asarray(self.b), self.spec
        return jax.jit(lambda wkey: solve.sketch_least_norm(spec, wkey, A, b))


def make_sketch_solve_compute(
    spec: sk.SketchSpec,
    base_key: jax.Array,
    A: jax.Array,
    b: jax.Array,
    *,
    reg: float = 0.0,
    method: str = "fused",
) -> SketchSolveCompute:
    """One Algorithm-1 worker as a ``compute_fn``: (worker, round) ↦ x̂ ∈ R^d."""
    return SketchSolveCompute(spec, base_key, A, b, reg=reg, method=method)


def make_least_norm_compute(
    spec: sk.SketchSpec,
    base_key: jax.Array,
    A: jax.Array,
    b: jax.Array,
) -> LeastNormCompute:
    """§V right-sketch worker (n < d) as a ``compute_fn``."""
    return LeastNormCompute(spec, base_key, A, b)


# ----------------------------------------------------------------- error estimators


def theory_error_fn(spec: sk.SketchSpec, d: int) -> Callable[[np.ndarray, int], float]:
    """Predicted relative error after q′ arrivals — Theorem 1, exact for Gaussian
    sketches (documented heuristic otherwise). Ignores x̄: a pure function of the
    realized count, so stopping is decided without touching the data."""
    single = theory.gaussian_single_error(spec.m, d)

    def err(_xbar: np.ndarray, count: int) -> float:
        return single / max(count, 1)

    return err


def probe_error_fn(A_probe: jax.Array, b_probe: jax.Array) -> Callable[[np.ndarray, int], float]:
    """Held-out residual probe: (f_p(x̄) − f_p*) / f_p* on probe rows.

    The probe's own optimum f_p* is computed once; each arrival costs one (n_p, d)
    matvec. With probe rows subsampled from (A, b) this estimates the paper's
    relative approximation error without knowing the full problem's f*."""
    x_p = solve.lstsq(A_probe, b_probe)
    fstar = float(solve.residual_cost(A_probe, b_probe, x_p))

    @jax.jit
    def _cost(x):
        return solve.residual_cost(A_probe, b_probe, x)

    def err(xbar: np.ndarray, _count: int) -> float:
        f = float(_cost(jnp.asarray(xbar, A_probe.dtype)))
        return (f - fstar) / max(fstar, 1e-30)

    return err


def subsample_probe(
    key: jax.Array, A: jax.Array, b: jax.Array, rows: int = 1024
) -> Tuple[jax.Array, jax.Array]:
    """Uniform row probe of (A, b) for :func:`probe_error_fn`."""
    n = A.shape[0]
    idx = jax.random.choice(key, n, (min(rows, n),), replace=False)
    return A[idx], b[idx]


def resolve_error_fn(
    error_fn: Union[None, str, Callable[[np.ndarray, int], float]],
    spec: sk.SketchSpec,
    key: jax.Array,
    A: jax.Array,
    b: jax.Array,
    *,
    probe_rows: int = 1024,
) -> Optional[Callable[[np.ndarray, int], float]]:
    """``"theory"`` / ``"probe"`` / callable / None → the engine's error callback."""
    if error_fn == "theory":
        return theory_error_fn(spec, A.shape[1])
    if error_fn == "probe":
        pk = jax.random.fold_in(key, 0x9B0BE)
        return probe_error_fn(*subsample_probe(pk, A, b, rows=probe_rows))
    return error_fn


# ------------------------------------------------------------------- one-call driver


def serverless_sketch_solve(
    spec: sk.SketchSpec,
    key: jax.Array,
    A: jax.Array,
    b: jax.Array,
    *,
    q: int,
    latency: LatencyModel,
    config: Optional[RuntimeConfig] = None,
    rounds: int = 1,
    reg: float = 0.0,
    method: str = "fused",
    error_fn: Union[None, str, Callable[[np.ndarray, int], float]] = None,
    probe_rows: int = 1024,
    backend: Union[None, str, ExecutorBackend] = None,
    deadline: Union[None, float, DeadlinePolicy] = None,
) -> RuntimeResult:
    """Algorithm 1 on the async engine: ``rounds`` waves of ``q`` workers, averaged
    as they arrive. ``error_fn``: a callable, ``"theory"``, ``"probe"``, or None
    (None still runs every task; "theory"/"probe" also enable the early-stop
    comparison when ``config.target_error`` is set). ``backend`` selects the
    executor (``"inline"``/``"thread"``/``"process"``, default ``config.backend``);
    ``deadline`` an optional :class:`~repro.runtime.engine.DeadlinePolicy`.
    """
    error_fn = resolve_error_fn(error_fn, spec, key, A, b, probe_rows=probe_rows)
    compute = make_sketch_solve_compute(spec, key, A, b, reg=reg, method=method)
    tasks: Sequence[Tuple[int, int]] = [(w, r) for r in range(rounds) for w in range(q)]
    engine = ServerlessEngine(compute, latency, config, backend=backend, deadline=deadline)
    return engine.run(tasks=tasks, error_fn=error_fn)
