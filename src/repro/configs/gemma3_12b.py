"""Gemma-3 12B [hf:google/gemma-3; unverified]. 5:1 local:global attention, 128k."""
from repro.configs.base import ArchConfig, register


@register
def gemma3_12b() -> ArchConfig:
    return ArchConfig(
        name="gemma3-12b",
        family="decoder",
        num_layers=48,
        d_model=3840,
        num_heads=16,
        num_kv_heads=8,
        head_dim=240,
        d_ff=15360,
        vocab_size=262144,
        attn_kind="local_global",
        window=1024,
        local_global_ratio=5,
        rope_theta=1e6,
        supports_long_context=True,
        long_context_note=(
            "5/6 of layers are SWA-1024 (rolling cache); the 1/6 global layers keep a "
            "sequence-sharded 500k KV cache over the data axis"
        ),
    )
