"""Pixtral-12B [hf:mistralai/Pixtral-12B-2409; unverified].

ViT frontend is a STUB per the assignment: input_specs() feeds precomputed patch
embeddings (B, 256, 1024) which the backbone projects into d_model and prepends to the
token stream. Backbone = Mistral-NeMo-like dense decoder.
"""
from repro.configs.base import ArchConfig, register


@register
def pixtral_12b() -> ArchConfig:
    return ArchConfig(
        name="pixtral-12b",
        family="vlm",
        num_layers=40,
        d_model=5120,
        num_heads=32,
        num_kv_heads=8,
        head_dim=160,
        d_ff=14336,
        vocab_size=131072,
        attn_kind="full",
        rope_theta=1e6,
        vlm=True,
        num_image_tokens=256,
        vit_dim=1024,
        supports_long_context=False,
        long_context_note="pure full attention: 500k KV cache infeasible and beyond published context",
    )
