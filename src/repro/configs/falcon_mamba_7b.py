"""Falcon-Mamba 7B [arXiv:2410.05355]. Attention-free mamba1 stack."""
from repro.configs.base import ArchConfig, register


@register
def falcon_mamba_7b() -> ArchConfig:
    return ArchConfig(
        name="falcon-mamba-7b",
        family="ssm",
        num_layers=64,
        d_model=4096,
        num_heads=0,
        num_kv_heads=0,
        d_ff=0,
        vocab_size=65024,
        ssm_state=16,
        d_conv=4,
        expand=2,
        supports_long_context=True,
        long_context_note="SSM: O(1) recurrent state, long_500k is the native regime",
    )
