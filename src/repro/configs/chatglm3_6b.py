"""ChatGLM3-6B [arXiv:2406.12793]. GQA kv=2, 2d-RoPE (rotary on half the dims)."""
from repro.configs.base import ArchConfig, register


@register
def chatglm3_6b() -> ArchConfig:
    return ArchConfig(
        name="chatglm3-6b",
        family="decoder",
        num_layers=28,
        d_model=4096,
        num_heads=32,
        num_kv_heads=2,
        head_dim=128,
        d_ff=13696,
        vocab_size=65024,
        attn_kind="full",
        rope_fraction=0.5,
        supports_long_context=False,
        long_context_note="pure full attention: 500k KV cache infeasible",
    )
