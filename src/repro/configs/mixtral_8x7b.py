"""Mixtral 8x7B [arXiv:2401.04088]. 8 experts top-2, sliding-window attention."""
from repro.configs.base import ArchConfig, register


@register
def mixtral_8x7b() -> ArchConfig:
    return ArchConfig(
        name="mixtral-8x7b",
        family="moe",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=32000,
        attn_kind="swa",
        window=4096,
        moe=True,
        num_experts=8,
        top_k=2,
        supports_long_context=True,
        long_context_note="SWA-4096 bounds the live KV window; rolling cache holds window tokens",
    )
