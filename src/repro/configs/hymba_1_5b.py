"""Hymba-1.5B [arXiv:2411.13676]. Parallel attention + mamba heads per layer."""
from repro.configs.base import ArchConfig, register


@register
def hymba_1_5b() -> ArchConfig:
    return ArchConfig(
        name="hymba-1.5b",
        family="hybrid",
        num_layers=32,
        d_model=1600,
        num_heads=25,
        num_kv_heads=5,
        head_dim=64,
        d_ff=5504,
        vocab_size=32001,
        attn_kind="swa",     # hymba uses SWA + meta tokens on most layers
        window=1024,
        ssm_state=16,
        d_conv=4,
        expand=2,
        hybrid=True,
        supports_long_context=True,
        long_context_note="hybrid: SSM branch carries long-range state; attn branch is SWA (rolling cache)",
    )
