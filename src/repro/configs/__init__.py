"""Config registry — importing this package registers every assigned architecture."""
from repro.configs.base import ArchConfig, ShapeSpec, SHAPES, get_config, list_archs, shape_applicable
from repro.configs import (
    pixtral_12b,
    grok_1_314b,
    mixtral_8x7b,
    minicpm3_4b,
    gemma3_12b,
    chatglm3_6b,
    granite_3_8b,
    hymba_1_5b,
    whisper_small,
    falcon_mamba_7b,
    paper_lsq,
)

ASSIGNED = [
    "pixtral-12b",
    "grok-1-314b",
    "mixtral-8x7b",
    "minicpm3-4b",
    "gemma3-12b",
    "chatglm3-6b",
    "granite-3-8b",
    "hymba-1.5b",
    "whisper-small",
    "falcon-mamba-7b",
]
