"""Whisper-small [arXiv:2212.04356]. Enc-dec; conv frontend STUBBED — input_specs()
provides precomputed (B, 1500, d_model) frame embeddings."""
from repro.configs.base import ArchConfig, register


@register
def whisper_small() -> ArchConfig:
    return ArchConfig(
        name="whisper-small",
        family="encdec",
        num_layers=12,        # decoder layers
        d_model=768,
        num_heads=12,
        num_kv_heads=12,
        head_dim=64,
        d_ff=3072,
        vocab_size=51865,
        attn_kind="full",
        encdec=True,
        enc_layers=12,
        enc_seq=1500,
        supports_long_context=False,
        long_context_note="enc-dec full attention; 500k decode far beyond the 448-token decoder context",
    )
