"""Granite-3 8B [hf:ibm-granite/granite-3.0; hf]. Plain GQA dense decoder."""
from repro.configs.base import ArchConfig, register


@register
def granite_3_8b() -> ArchConfig:
    return ArchConfig(
        name="granite-3-8b",
        family="decoder",
        num_layers=40,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=12800,
        vocab_size=49155,
        attn_kind="full",
        supports_long_context=False,
        long_context_note="pure full attention: 500k KV cache infeasible",
    )
