"""The paper's own workloads (not an LM arch): distributed sketched regression configs
matching the numerical-results section, regenerated synthetically (offline container).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class RegressionConfig:
    name: str
    n: int
    d: int
    m: int                 # sketch dimension
    m_prime: int = 0       # hybrid first-stage sample size
    q: int = 100           # workers
    sketch: str = "sjlt"
    s: int = 20            # SJLT nonzeros per column (paper's Fig. 2 uses s=20)
    heavy_tail_df: float = 0.0   # student-t degrees of freedom (0 = gaussian data)
    planted: bool = False


# Paper Fig. 1 (airline, n=1.21e8×774, m=5e5, q=100) scaled to container size while
# preserving the ratios m/d ≈ 646 → we keep m/d large and n/m ≈ 242.
FIG1 = RegressionConfig("fig1_airline", n=2_000_000, d=774 // 4, m=8000, m_prime=80_000, q=100)

# Paper Fig. 3a: A ∈ R^{1e7×1e3}, m=1e4, m'=1e5, student-t(1.5), q=200.
FIG3A = RegressionConfig(
    "fig3a_synth", n=500_000, d=250, m=2500, m_prime=25_000, q=200, heavy_tail_df=1.5, planted=True
)

# Paper Fig. 4a: least-norm, n=50, d=1000, m=200, m'=500.
FIG4A = RegressionConfig("fig4a_leastnorm", n=50, d=1000, m=200, m_prime=500, q=100, sketch="gaussian")
