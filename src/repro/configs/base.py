"""Architecture config system: one frozen dataclass, a registry, and shape specs.

Every assigned architecture registers an ``ArchConfig`` (full published size) and can
produce a ``reduced()`` copy for CPU smoke tests. Input shapes are global; the launcher
owns how they shard over the mesh.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Optional

_REGISTRY: Dict[str, Callable[[], "ArchConfig"]] = {}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    # identity
    name: str
    family: str                     # decoder | moe | ssm | hybrid | encdec | vlm
    # trunk
    num_layers: int = 0
    d_model: int = 0
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0               # 0 -> d_model // num_heads
    d_ff: int = 0
    vocab_size: int = 0
    # attention pattern
    attn_kind: str = "full"         # full | swa | local_global
    window: int = 0                 # SWA window (swa / local layers)
    local_global_ratio: int = 0     # gemma3: 5 local per 1 global
    rope_theta: float = 1e4
    rope_fraction: float = 1.0      # chatglm 2d-rope: rotate only this fraction of dims
    # MLA (minicpm3)
    mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # MoE
    moe: bool = False
    num_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # SSM (mamba1)
    ssm_state: int = 0
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0                # 0 -> ceil(d_model / 16)
    # hybrid (hymba): parallel attn + ssm heads in every layer
    hybrid: bool = False
    # encoder-decoder (whisper)
    encdec: bool = False
    enc_layers: int = 0
    enc_seq: int = 1500             # whisper frame count after the conv stub
    # VLM (pixtral)
    vlm: bool = False
    num_image_tokens: int = 256
    vit_dim: int = 1024
    # numerics / training
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    tie_embeddings: bool = False
    # shape applicability
    supports_long_context: bool = False   # may run long_500k
    long_context_note: str = ""

    # ------------------------------------------------------------------ derived
    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 256 (Megatron-style): embedding and
        unembedding shard over the 16-way tensor axis and want 128-lane alignment.
        The padded ids are ordinary trainable classes that no label ever selects;
        serving masks them out at sampling time."""
        m = 256
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def resolved_dt_rank(self) -> int:
        return self.dt_rank or -(-self.d_model // 16)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    def param_count(self) -> int:
        """Approximate parameter count (embedding + layers), for roofline MODEL_FLOPS."""
        d, V = self.d_model, self.vocab_size
        total = V * d * (1 if self.tie_embeddings else 2)
        hd = self.resolved_head_dim

        def attn_params() -> int:
            if self.mla:
                q_up_in = self.q_lora_rank or d
                p = d * (self.q_lora_rank or 0)
                p += q_up_in * self.num_heads * (self.qk_nope_dim + self.qk_rope_dim)
                p += d * (self.kv_lora_rank + self.qk_rope_dim)
                p += self.kv_lora_rank * self.num_heads * (self.qk_nope_dim + self.v_head_dim)
                p += self.num_heads * self.v_head_dim * d
                return p
            return d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd + self.num_heads * hd * d

        def ffn_params() -> int:
            if self.moe:
                return self.num_experts * 3 * d * self.d_ff + d * self.num_experts
            return 3 * d * self.d_ff

        def ssm_params() -> int:
            di, r, st = self.d_inner, self.resolved_dt_rank, self.ssm_state
            return d * 2 * di + self.d_conv * di + di * (r + 2 * st) + r * di + di * st + di + di * d

        per_layer = 0
        if self.family == "ssm":
            per_layer = ssm_params()
        elif self.family == "hybrid":
            per_layer = attn_params() + ssm_params() + ffn_params()
        else:
            per_layer = attn_params() + ffn_params()
        total += self.num_layers * per_layer
        if self.encdec:
            enc_per = d * self.num_heads * hd * 2 + 2 * d * self.num_kv_heads * hd * 1 + 3 * d * self.d_ff
            total += self.enc_layers * enc_per
            total += self.num_layers * (d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd + self.num_heads * hd * d)  # cross-attn
        if self.vlm:
            total += self.vit_dim * d
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k of num_experts)."""
        if not self.moe:
            return self.param_count()
        d = self.d_model
        dense = self.param_count() - self.num_layers * self.num_experts * 3 * d * self.d_ff
        return dense + self.num_layers * self.top_k * 3 * d * self.d_ff

    # ------------------------------------------------------------------ reduction
    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests (one fwd/train step)."""
        heads = min(self.num_heads, 4) or 4
        kv = max(1, min(self.num_kv_heads, 2)) if self.num_kv_heads else heads
        # local_global archs need a full period of layers (e.g. gemma3's 5 local + 1
        # global) for the grouped decode-cache path to be exercised.
        min_layers = (self.local_global_ratio + 1) if self.local_global_ratio > 0 else 2
        changes = dict(
            num_layers=min(self.num_layers, min_layers),
            d_model=64,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=16,
            d_ff=0 if self.family == "ssm" else 128,
            vocab_size=256,
            window=min(self.window, 8) if self.window else 0,
            enc_layers=min(self.enc_layers, 2),
            enc_seq=16 if self.encdec else self.enc_seq,
            num_image_tokens=4 if self.vlm else self.num_image_tokens,
            vit_dim=32 if self.vlm else self.vit_dim,
            num_experts=min(self.num_experts, 4) if self.moe else 0,
            q_lora_rank=16 if self.mla else 0,
            kv_lora_rank=16 if self.mla else 0,
            qk_nope_dim=8 if self.mla else 0,
            qk_rope_dim=8 if self.mla else 0,
            v_head_dim=16 if self.mla else 0,
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            dt_rank=8 if self.ssm_state else 0,
            dtype="float32",
        )
        return dataclasses.replace(self, **changes)


# ---------------------------------------------------------------------- registry


def register(fn: Callable[[], ArchConfig]) -> Callable[[], ArchConfig]:
    cfg = fn()
    _REGISTRY[cfg.name] = fn
    return fn


def get_config(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        # import all config modules lazily so the registry is populated
        from repro import configs as _  # noqa

    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs() -> list[str]:
    from repro import configs as _  # noqa

    return sorted(_REGISTRY)


# ---------------------------------------------------------------------- shapes


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Whether (arch, shape) is a runnable cell; reason string when skipped."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, cfg.long_context_note or "pure full-attention stack: 500k dense KV cache is quadratic-memory infeasible"
    return True, ""
