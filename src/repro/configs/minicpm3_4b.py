"""MiniCPM3-4B [hf:openbmb/MiniCPM3-4B]. MLA (multi-head latent attention)."""
from repro.configs.base import ArchConfig, register


@register
def minicpm3_4b() -> ArchConfig:
    return ArchConfig(
        name="minicpm3-4b",
        family="decoder",
        num_layers=62,
        d_model=2560,
        num_heads=40,
        num_kv_heads=40,   # MLA: per-head latent KV, kv==q heads
        head_dim=64,
        d_ff=6400,
        vocab_size=73448,
        attn_kind="full",
        mla=True,
        q_lora_rank=768,
        kv_lora_rank=256,
        qk_nope_dim=64,
        qk_rope_dim=32,
        v_head_dim=64,
        supports_long_context=False,
        long_context_note="full attention; MLA shrinks the cache ~9x but 500k still exceeds the published 32k context; skipped",
    )
