"""Grok-1 314B MoE [hf:xai-org/grok-1; unverified]. 8 experts, top-2."""
from repro.configs.base import ArchConfig, register


@register
def grok_1_314b() -> ArchConfig:
    return ArchConfig(
        name="grok-1-314b",
        family="moe",
        num_layers=64,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        head_dim=128,
        d_ff=32768,
        vocab_size=131072,
        attn_kind="full",
        moe=True,
        num_experts=8,
        top_k=2,
        supports_long_context=False,
        long_context_note="pure full attention: 500k KV cache infeasible (64L × 8kv × 128hd)",
    )
