"""Pallas TPU kernel: Walsh-Hadamard transform of a VMEM-resident row tile.

TPU adaptation (vs. the GPU/CPU butterfly loop): we *matmul* with small Hadamard
matrices so the MXU does the work. A tile of R rows is factored R = B · 128 and

    H_R = H_B ⊗ H_128    (Sylvester / Kronecker identity)

so the transform is two MXU matmuls per tile:
    t[J, i, D] = Σ_j H_128[i, j] · x[J, j, D]          (within 128-row groups)
    y[I, i, D] = Σ_J H_B[I, J]  · t[J, i, D]           (across the B groups)

FLOP cost is R·128 + R·B multiplies per element instead of R·log₂R adds — on paper
worse, but it is dense 128-aligned MXU work instead of lane-hostile shuffles, and the
tile stays in VMEM for both passes. Tiles larger than one VMEM block are handled by
the Kronecker factorization one level up, in ops.py (grid pass 1: within-tile; grid
pass 2: across tiles on a reshaped view — same kernel both times).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fwht_tile_kernel(h_outer_ref, h_inner_ref, x_ref, o_ref):
    """One (R, DB) tile: y = (H_B ⊗ H_128) @ x, both factors as MXU matmuls."""
    x = x_ref[...]
    rows, db = x.shape
    k = h_inner_ref.shape[0]  # inner Hadamard size (<= 128 only when rows < 128)
    b = rows // k
    hi = h_inner_ref[...]
    x = x.reshape(b, k, db)
    t = jax.lax.dot_general(
        hi, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (k_out, b, db) with dims (i, J, D)
    t = jnp.transpose(t, (1, 0, 2))  # (J=b, i=k, D)
    if b > 1:
        ho = h_outer_ref[...]
        t2 = t.reshape(b, k * db)
        y = jnp.dot(ho, t2, preferred_element_type=jnp.float32)  # (I=b, k*db)
        o_ref[...] = y.reshape(rows, db).astype(o_ref.dtype)
    else:
        o_ref[...] = t.reshape(rows, db).astype(o_ref.dtype)


def fwht_tiles(
    x: jax.Array,
    h_outer: jax.Array,
    h_inner: jax.Array,
    *,
    tile_rows: int,
    block_d: int,
    interpret: bool = True,
) -> jax.Array:
    """Apply H_{tile_rows} independently to each contiguous group of tile_rows rows.

    x: (n, d) with n % tile_rows == 0 and d % block_d == 0.
    h_inner: (k, k) with k = min(128, tile_rows); h_outer: (tile_rows//k,)².
    """
    n, d = x.shape
    assert n % tile_rows == 0 and d % block_d == 0, (n, d, tile_rows, block_d)
    grid = (n // tile_rows, d // block_d)
    return pl.pallas_call(
        _fwht_tile_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(h_outer.shape, lambda i, j: (0, 0)),
            pl.BlockSpec(h_inner.shape, lambda i, j: (0, 0)),
            pl.BlockSpec((tile_rows, block_d), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((tile_rows, block_d), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=interpret,
    )(h_outer, h_inner, x)
