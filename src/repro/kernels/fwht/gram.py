"""Pallas TPU kernel: fused SRHT sketch→Gram — G = (SA)ᵀ(SA) in ONE pass over A.

The FWHT formulation of the SRHT needs the whole (padded) column dimension resident
before any output row is final — it cannot stream row tiles of A. The streaming form
instead materializes S *tiles* directly from the Sylvester closed form

    S[r, j] = (1/√m) · (−1)^popcount(rows[r] & j) · D[j]

(a popcount + sign per element — no transform, no HBM traffic for S) and follows the
same single-pass recipe as the Gaussian/SJLT gram kernels: grid over row tiles of A,
an (m, d) VMEM scratch accumulator across the sequential grid, and one tiny (d, d)
contraction at the final step. Per element this costs an AND + popcount versus the
FWHT's log n adds; for the paper's m = O(d) ≪ n regime both paths are dominated by
streaming A, and only this form never needs all of A at once.

The sampled-row ids arrive padded with −1 (masked in-kernel), so ``m`` need not be a
multiple of the sublane tiling.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import common


def srht_gram_tiles(
    A: jax.Array,
    rows: jax.Array,
    key_words: jax.Array,
    *,
    block_n: int,
    inv_sqrt_m: float,
    interpret: bool = True,
) -> jax.Array:
    """G = (SA)ᵀ(SA) for the SRHT with sampled Hadamard rows ``rows`` and Rademacher
    diagonal keyed by ``key_words``. A: (n_pad, d_pad) zero-padded; rows: (m_pad, 1)
    int32, padded entries −1. Returns (d_pad, d_pad) f32."""
    n, d = A.shape
    m_pad = rows.shape[0]
    n_tiles = n // block_n

    def kernel(kw_ref, r_ref, a_ref, o_ref, acc_ref):
        ni = pl.program_id(0)

        @pl.when(ni == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        k0 = kw_ref[0]
        k1 = kw_ref[1]
        r = r_ref[...]  # (m_pad, 1) int32, −1 marks padding
        j = (ni * block_n).astype(jnp.uint32) + jax.lax.broadcasted_iota(
            jnp.uint32, (1, block_n), 1
        )
        parity = jax.lax.population_count(r.astype(jnp.uint32) & j)  # (m_pad, block_n)
        h = (1 - 2 * (parity & jnp.uint32(1)).astype(jnp.int32)).astype(jnp.float32)
        dsign = common.counter_rademacher(k0, k1, j, jnp.uint32(0))  # (1, block_n)
        s_tile = jnp.where(r >= 0, h * dsign * jnp.float32(inv_sqrt_m), 0.0)
        acc_ref[...] += jnp.dot(s_tile, a_ref[...], preferred_element_type=jnp.float32)

        @pl.when(ni == n_tiles - 1)
        def _finish():
            acc = acc_ref[...]
            o_ref[...] = jax.lax.dot_general(
                acc, acc, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
            )

    return pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((2,), lambda ni: (0,)),
            pl.BlockSpec((m_pad, 1), lambda ni: (0, 0)),
            pl.BlockSpec((block_n, d), lambda ni: (ni, 0)),
        ],
        out_specs=pl.BlockSpec((d, d), lambda ni: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((d, d), jnp.float32),
        scratch_shapes=[pltpu.VMEM((m_pad, d), jnp.float32)],
        interpret=interpret,
    )(key_words, rows, A)


def srht_gram_tiles_multi(
    A: jax.Array,
    rows: jax.Array,
    key_words: jax.Array,
    *,
    block_n: int,
    inv_sqrt_m: float,
    interpret: bool = True,
) -> jax.Array:
    """All q workers' SRHT Grams from ONE launch / ONE read of A.

    ``rows``: (q, m_pad, 1) sampled Hadamard row ids (−1 padding); ``key_words``:
    (q, 2) Rademacher-diagonal keys. The Hadamard column-index row ``j`` is built
    once per grid step; the popcount parity, diagonal signs, and scatter matmul
    run per worker in a static unroll. Output slice w is bitwise equal to a
    single :func:`srht_gram_tiles` launch for worker w.
    """
    n, d = A.shape
    q, m_pad, _ = rows.shape
    n_tiles = n // block_n

    def kernel(kw_ref, r_ref, a_ref, o_ref, acc_ref):
        ni = pl.program_id(0)

        @pl.when(ni == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        a = a_ref[...]
        j = (ni * block_n).astype(jnp.uint32) + jax.lax.broadcasted_iota(
            jnp.uint32, (1, block_n), 1
        )
        for w in range(q):
            r = r_ref[w]  # (m_pad, 1) int32, −1 marks padding
            parity = jax.lax.population_count(r.astype(jnp.uint32) & j)
            h = (1 - 2 * (parity & jnp.uint32(1)).astype(jnp.int32)).astype(jnp.float32)
            dsign = common.counter_rademacher(kw_ref[w, 0], kw_ref[w, 1], j, jnp.uint32(0))
            s_tile = jnp.where(r >= 0, h * dsign * jnp.float32(inv_sqrt_m), 0.0)
            acc_ref[w] += jnp.dot(s_tile, a, preferred_element_type=jnp.float32)

        @pl.when(ni == n_tiles - 1)
        def _finish():
            for w in range(q):
                acc = acc_ref[w]
                o_ref[w] = jax.lax.dot_general(
                    acc, acc, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
                )

    return pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((q, 2), lambda ni: (0, 0)),
            pl.BlockSpec((q, m_pad, 1), lambda ni: (0, 0, 0)),
            pl.BlockSpec((block_n, d), lambda ni: (ni, 0)),
        ],
        out_specs=pl.BlockSpec((q, d, d), lambda ni: (0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((q, d, d), jnp.float32),
        scratch_shapes=[pltpu.VMEM((q, m_pad, d), jnp.float32)],
        interpret=interpret,
    )(key_words, rows, A)
