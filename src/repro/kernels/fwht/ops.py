"""Public FWHT op: arbitrary power-of-two n via two Kronecker grid passes.

    H_n = H_{n1} ⊗ H_{tile}                (n = n1 · tile)

Pass 1 applies H_tile within each contiguous tile of rows (one kernel tile each).
Pass 2 views the result as (n1, tile·d) — each *column* of that view is a stride-tile
slice — and applies H_{n1} across tiles with the same kernel. Between the passes the
data never needs a physical transpose: the reshape is contiguous because pass-2 rows
are exactly the pass-1 tiles.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.fwht import gram as K_gram
from repro.kernels.fwht import kernel as K

MAX_TILE_ROWS = 4096  # 4096×256 f32 tile = 4 MiB — well inside a v5e core's ~16 MiB more VMEM
DEFAULT_BLOCK_D = 256


def _hadamard_factors(rows: int, dtype):
    k = min(128, rows)
    b = rows // k
    return common.hadamard_matrix(b, dtype), common.hadamard_matrix(k, dtype)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def fwht(x: jax.Array, *, block_d: int = DEFAULT_BLOCK_D, interpret: bool | None = None) -> jax.Array:
    """Unnormalized Walsh-Hadamard transform along axis 0 of x: (n, d), n pow2."""
    interpret = common.resolve_interpret(interpret)
    orig_ndim = x.ndim
    if x.ndim == 1:
        x = x[:, None]
    n, d = x.shape
    if n & (n - 1):
        raise ValueError(f"FWHT needs power-of-two n, got {n}")
    dtype = x.dtype
    xf = x.astype(jnp.float32)

    bd = min(block_d, max(128, d))
    d_pad = common.round_up(d, bd)
    xf = common.pad_axis_to(xf, 1, d_pad)

    tile = min(n, MAX_TILE_ROWS)
    n1 = n // tile

    ho, hi = _hadamard_factors(tile, jnp.float32)
    y = K.fwht_tiles(xf, ho, hi, tile_rows=tile, block_d=bd, interpret=interpret)

    if n1 > 1:
        # Pass 2: rows of the (n1, tile*d_pad) view are the pass-1 tiles.
        y2 = y.reshape(n1, tile * d_pad)
        bd2 = 512 if (tile * d_pad) % 512 == 0 else bd
        ho2, hi2 = _hadamard_factors(n1, jnp.float32)
        y2 = K.fwht_tiles(y2, ho2, hi2, tile_rows=n1, block_d=bd2, interpret=interpret)
        y = y2.reshape(n, d_pad)

    return y[:, :d].astype(dtype) if orig_ndim == 2 else y[:, 0].astype(dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def srht_gram(
    A: jax.Array, rows: jax.Array, key_words: jax.Array, *, interpret: bool | None = None
) -> jax.Array:
    """G = (SA)ᵀ(SA) for the SRHT in one fused streamed pass (no FWHT, no SA in HBM).

    ``A``: (n, d) *already sign-flipped is NOT expected* — the Rademacher diagonal D
    keyed by ``key_words`` is applied inside the kernel via the Sylvester closed form.
    ``rows``: (m,) sampled Hadamard row ids. Returns (d, d) f32.
    """
    interpret = common.resolve_interpret(interpret)
    n, d = A.shape
    m = rows.shape[0]
    bn = min(MAX_TILE_ROWS, common.round_up(n, 8))
    n_pad = common.round_up(n, bn)
    d_pad = common.round_up(d, 128)
    m_pad = common.round_up(m, 8)

    Af = common.pad_axis_to(common.pad_axis_to(A.astype(jnp.float32), 0, n_pad), 1, d_pad)
    rows_p = (common.pad_axis_to(rows.astype(jnp.int32) + 1, 0, m_pad) - 1).reshape(m_pad, 1)

    G = K_gram.srht_gram_tiles(
        Af,
        rows_p,
        key_words,
        block_n=bn,
        inv_sqrt_m=1.0 / math.sqrt(m),
        interpret=interpret,
    )
    return G[:d, :d]


@functools.partial(jax.jit, static_argnames=("interpret",))
def srht_gram_multi(
    A: jax.Array, rows: jax.Array, key_words: jax.Array, *, interpret: bool | None = None
) -> jax.Array:
    """All q workers' SRHT Grams from ONE launch / ONE read of A.

    ``rows``: (q, m) per-worker sampled Hadamard rows; ``key_words``: (q, 2)
    diagonal keys. Returns (q, d, d) f32, slice w bitwise-identical to
    ``srht_gram(A, rows[w], key_words[w])``.
    """
    interpret = common.resolve_interpret(interpret)
    n, d = A.shape
    q, m = rows.shape
    bn = min(MAX_TILE_ROWS, common.round_up(n, 8))
    n_pad = common.round_up(n, bn)
    d_pad = common.round_up(d, 128)
    m_pad = common.round_up(m, 8)

    Af = common.pad_axis_to(common.pad_axis_to(A.astype(jnp.float32), 0, n_pad), 1, d_pad)
    rows_p = (common.pad_axis_to(rows.astype(jnp.int32) + 1, 1, m_pad) - 1).reshape(q, m_pad, 1)

    G = K_gram.srht_gram_tiles_multi(
        Af,
        rows_p,
        key_words,
        block_n=bn,
        inv_sqrt_m=1.0 / math.sqrt(m),
        interpret=interpret,
    )
    return G[:, :d, :d]


def flops_and_bytes(n: int, d: int) -> dict:
    """Structural roofline terms for one FWHT (matmul formulation)."""
    tile = min(n, MAX_TILE_ROWS)
    n1 = n // tile
    k = min(128, tile)
    b = tile // k
    f = 2 * n * d * (k + b)  # pass 1
    if n1 > 1:
        f += 2 * n * d * n1  # pass 2
    return {"flops": f, "bytes": 4 * n * d * (2 if n1 == 1 else 4)}
