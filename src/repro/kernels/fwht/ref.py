"""Pure-jnp oracle for the blocked Walsh-Hadamard transform."""
from __future__ import annotations

import jax.numpy as jnp


def fwht(x: jnp.ndarray) -> jnp.ndarray:
    """Unnormalized Walsh-Hadamard transform along axis 0 (HᵀH = n·I).

    x: (n, ...) with n a power of two. Iterative radix-2 butterflies.
    """
    n = x.shape[0]
    if n & (n - 1):
        raise ValueError(f"FWHT needs power-of-two length, got {n}")
    h = 1
    while h < n:
        x = x.reshape(n // (2 * h), 2, h, *x.shape[1:])
        a = x[:, 0]
        b = x[:, 1]
        x = jnp.stack([a + b, a - b], axis=1).reshape(n, *x.shape[3:])
        h *= 2
    return x
