from repro.kernels.fwht import ops, ref
from repro.kernels.fwht.ops import fwht
