"""Public packed-sign Rademacher ops: the cheap-RNG dense sketch family."""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.rademacher import gram as K_gram
from repro.kernels.rademacher import kernel as K

BLOCK_M = 256
BLOCK_N = 512
BLOCK_D = 256


def _block_n(n: int) -> int:
    # One threefry word covers 32 columns, so the row-tile width must be a
    # multiple of 32 (zero-pad A up to it; zero rows contribute nothing).
    return min(BLOCK_N, common.round_up(n, 32))


@functools.partial(jax.jit, static_argnames=("m", "interpret"))
def rademacher_sketch(
    key: jax.Array, A: jax.Array, m: int, *, interpret: bool | None = None
) -> jax.Array:
    """S @ A with S = ±1/√m generated in-core (1 threefry per 32 entries)."""
    interpret = common.resolve_interpret(interpret)
    orig_ndim = A.ndim
    if A.ndim == 1:
        A = A[:, None]
    n, d = A.shape
    dtype = A.dtype

    bm = min(BLOCK_M, common.round_up(m, 8))
    bn = _block_n(n)
    bd = min(BLOCK_D, common.round_up(d, 128))
    m_pad = common.round_up(m, bm)
    n_pad = common.round_up(n, bn)
    d_pad = common.round_up(d, bd)

    Af = common.pad_axis_to(common.pad_axis_to(A.astype(jnp.float32), 0, n_pad), 1, d_pad)
    k0, k1 = common.key_to_words(key)
    key_words = jnp.stack([k0, k1])

    out = K.rademacher_tiles(
        Af,
        key_words,
        m_pad,
        block_m=bm,
        block_n=bn,
        block_d=bd,
        inv_sqrt_m=1.0 / math.sqrt(m),
        interpret=interpret,
    )
    out = out[:m, :d].astype(dtype)
    return out[:, 0] if orig_ndim == 1 else out


@functools.partial(jax.jit, static_argnames=("m", "interpret"))
def rademacher_gram(
    key: jax.Array, A: jax.Array, m: int, *, interpret: bool | None = None
) -> jax.Array:
    """G = (SA)ᵀ(SA) ∈ R^{d×d} in one fused pass — S and SA never touch HBM."""
    interpret = common.resolve_interpret(interpret)
    n, d = A.shape
    bn = _block_n(n)
    n_pad = common.round_up(n, bn)
    d_pad = common.round_up(d, 128)
    m_pad = common.round_up(m, 8)

    Af = common.pad_axis_to(common.pad_axis_to(A.astype(jnp.float32), 0, n_pad), 1, d_pad)
    k0, k1 = common.key_to_words(key)
    key_words = jnp.stack([k0, k1])

    G = K_gram.rademacher_gram_tiles(
        Af,
        key_words,
        m,
        m_pad,
        block_n=bn,
        inv_sqrt_m=1.0 / math.sqrt(m),
        interpret=interpret,
    )
    return G[:d, :d]


@functools.partial(jax.jit, static_argnames=("m", "interpret"))
def rademacher_gram_multi(
    keys: jax.Array, A: jax.Array, m: int, *, interpret: bool | None = None
) -> jax.Array:
    """All q workers' ``G_k`` from ONE launch / ONE read of A. ``keys``: (q,)
    PRNG keys; returns (q, d, d), slice w bitwise == ``rademacher_gram``."""
    interpret = common.resolve_interpret(interpret)
    n, d = A.shape
    bn = _block_n(n)
    n_pad = common.round_up(n, bn)
    d_pad = common.round_up(d, 128)
    m_pad = common.round_up(m, 8)

    Af = common.pad_axis_to(common.pad_axis_to(A.astype(jnp.float32), 0, n_pad), 1, d_pad)
    key_words = common.keys_to_words(keys)

    G = K_gram.rademacher_gram_tiles_multi(
        Af,
        key_words,
        m,
        m_pad,
        block_n=bn,
        inv_sqrt_m=1.0 / math.sqrt(m),
        interpret=interpret,
    )
    return G[:, :d, :d]


def flops_and_bytes(n: int, d: int, m: int) -> dict:
    """Structural roofline: same matmul as the Gaussian sketch but ~2 uint ops of
    RNG per element (120/32 threefry amortized + unpack) instead of ~60+."""
    rng_flops_per_elem = 4  # 120-op threefry per 32 entries + shift/mask/ select
    return {
        "flops": 2 * m * n * d + rng_flops_per_elem * m * n,
        "bytes": 4 * (n * d + m * d),
        "bytes_materialized": 4 * (m * n + n * d + m * d),
    }
