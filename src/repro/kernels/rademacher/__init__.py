from repro.kernels.rademacher import ops, ref
from repro.kernels.rademacher.ops import rademacher_gram, rademacher_gram_multi, rademacher_sketch
