"""Pure-jnp oracle for the packed-sign Rademacher sketch.

Materializes the same packed-contract S the kernels generate tile-by-tile
(sign(i, j) = bit j%32 of threefry(key, i, j//32)[0], scaled 1/√m), then does a
plain matmul. The kernels must match this to float precision.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.kernels import common


def sketch_matrix(key: jax.Array, m: int, n: int) -> jax.Array:
    """The full S ∈ R^{m×n} with ±1/√m packed-contract entries."""
    k0, k1 = common.key_to_words(key)
    signs = common.counter_rademacher_block(k0, k1, jnp.uint32(0), jnp.uint32(0), m, n)
    return signs * jnp.float32(1.0 / math.sqrt(m))


def rademacher_sketch(key: jax.Array, A: jax.Array, m: int) -> jax.Array:
    S = sketch_matrix(key, m, A.shape[0])
    return (S @ A.astype(jnp.float32)).astype(A.dtype)
