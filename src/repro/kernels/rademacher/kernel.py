"""Pallas TPU kernel: Rademacher sketch apply (S @ A) with packed in-core signs.

Same tiling as the Gaussian apply kernel (``..gaussian.kernel.gaussian_tiles``),
but each (block_m × block_n) S tile costs block_m·block_n/32 threefry calls and a
bit-unpack instead of one threefry + Box-Muller per element.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import common


def rademacher_tiles(
    A: jax.Array,
    key_words: jax.Array,
    m_pad: int,
    *,
    block_m: int,
    block_n: int,
    block_d: int,
    inv_sqrt_m: float,
    interpret: bool = True,
) -> jax.Array:
    """out = S @ A with S = ±1/√m from the packed sign stream. A: (n_pad, d_pad)
    zero-filled beyond the true n; ``block_n`` must be a multiple of 32."""
    n, d = A.shape
    grid = (m_pad // block_m, d // block_d, n // block_n)

    def kernel(kw_ref, a_ref, o_ref):
        mi = pl.program_id(0)
        ni = pl.program_id(2)

        @pl.when(ni == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        row0 = (mi * block_m).astype(jnp.uint32)
        col0 = (ni * block_n).astype(jnp.uint32)
        s_tile = common.packed_sign_tile(
            kw_ref[0], kw_ref[1], row0, col0, block_m, block_n
        ) * jnp.float32(inv_sqrt_m)
        o_ref[...] += jnp.dot(s_tile, a_ref[...], preferred_element_type=jnp.float32)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((2,), lambda mi, di, ni: (0,)),
            pl.BlockSpec((block_n, block_d), lambda mi, di, ni: (ni, di)),
        ],
        out_specs=pl.BlockSpec((block_m, block_d), lambda mi, di, ni: (mi, di)),
        out_shape=jax.ShapeDtypeStruct((m_pad, d), jnp.float32),
        interpret=interpret,
    )(key_words, A)
