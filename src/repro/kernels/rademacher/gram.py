"""Pallas TPU kernels: fused Rademacher sketch→Gram — the cheap-RNG dense family.

The Gaussian gram kernel is RNG-bound: every S entry costs one 20-round threefry
*plus* Box-Muller (log/sqrt/cos). A Rademacher sketch S[i,j] = ±1/√m is also
sub-gaussian (it satisfies the same JL/embedding moment bounds the paper's Thm-1
averaging analysis needs — see "Distributed Hybrid Sketching for ℓ2-Embeddings",
arXiv:2412.20301), but its randomness is ONE BIT per entry: one threefry call
yields 32 packed signs (``common.packed_sign_words``), a ~64× reduction in RNG
uint work and the complete removal of the transcendental pipeline.

Kernel structure is identical to the Gaussian gram kernels (grid over row tiles,
(m, d) VMEM accumulator, last-step Gram contraction; the multi-worker variant
keeps q accumulators and reads A once), only the S-tile generator differs:
words → bit-unpack → ±1, instead of threefry → Box-Muller.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import common


def _sign_tile(k0, k1, ni, m_pad: int, block_n: int, inv_sqrt_m: float, m: int):
    """The (m_pad, block_n) scaled ±1/√m S tile at row-tile ni (packed contract)."""
    col0 = (ni * block_n).astype(jnp.uint32)
    signs = common.packed_sign_tile(k0, k1, jnp.uint32(0), col0, m_pad, block_n)
    rows = jax.lax.broadcasted_iota(jnp.uint32, (m_pad, block_n), 0)
    return jnp.where(rows < jnp.uint32(m), signs * jnp.float32(inv_sqrt_m), 0.0)


def rademacher_gram_tiles(
    A: jax.Array,
    key_words: jax.Array,
    m: int,
    m_pad: int,
    *,
    block_n: int,
    inv_sqrt_m: float,
    interpret: bool = True,
) -> jax.Array:
    """G = (SA)ᵀ(SA) with S = ±1/√m generated in-core from packed sign words.
    A: (n_pad, d_pad) zero-filled; ``block_n`` must be a multiple of 32 (one
    threefry word per 32 columns). Returns (d_pad, d_pad) f32."""
    n, d = A.shape
    n_tiles = n // block_n

    def kernel(kw_ref, a_ref, o_ref, acc_ref):
        ni = pl.program_id(0)

        @pl.when(ni == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        s_tile = _sign_tile(kw_ref[0], kw_ref[1], ni, m_pad, block_n, inv_sqrt_m, m)
        acc_ref[...] += jnp.dot(s_tile, a_ref[...], preferred_element_type=jnp.float32)

        @pl.when(ni == n_tiles - 1)
        def _finish():
            acc = acc_ref[...]
            o_ref[...] = jax.lax.dot_general(
                acc, acc, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
            )

    return pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((2,), lambda ni: (0,)),
            pl.BlockSpec((block_n, d), lambda ni: (ni, 0)),
        ],
        out_specs=pl.BlockSpec((d, d), lambda ni: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((d, d), jnp.float32),
        scratch_shapes=[pltpu.VMEM((m_pad, d), jnp.float32)],
        interpret=interpret,
    )(key_words, A)


def rademacher_gram_tiles_multi(
    A: jax.Array,
    key_words: jax.Array,
    m: int,
    m_pad: int,
    *,
    block_n: int,
    inv_sqrt_m: float,
    interpret: bool = True,
) -> jax.Array:
    """All q workers' Rademacher Grams from ONE launch / ONE read of A.

    ``key_words``: (q, 2). Static worker unroll over a (q, m_pad, d) scratch —
    same shape discipline as :func:`..gaussian.gram.gaussian_gram_tiles_multi`;
    per-worker op sequence matches :func:`rademacher_gram_tiles` (bitwise)."""
    n, d = A.shape
    q = key_words.shape[0]
    n_tiles = n // block_n

    def kernel(kw_ref, a_ref, o_ref, acc_ref):
        ni = pl.program_id(0)

        @pl.when(ni == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        a = a_ref[...]
        for w in range(q):  # static unroll: q accumulators, one read of A
            s_tile = _sign_tile(kw_ref[w, 0], kw_ref[w, 1], ni, m_pad, block_n, inv_sqrt_m, m)
            acc_ref[w] += jnp.dot(s_tile, a, preferred_element_type=jnp.float32)

        @pl.when(ni == n_tiles - 1)
        def _finish():
            for w in range(q):
                acc = acc_ref[w]
                o_ref[w] = jax.lax.dot_general(
                    acc, acc, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
                )

    return pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((q, 2), lambda ni: (0, 0)),
            pl.BlockSpec((block_n, d), lambda ni: (ni, 0)),
        ],
        out_specs=pl.BlockSpec((q, d, d), lambda ni: (0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((q, d, d), jnp.float32),
        scratch_shapes=[pltpu.VMEM((q, m_pad, d), jnp.float32)],
        interpret=interpret,
    )(key_words, A)
