"""Shared kernel utilities: in-kernel counter RNG and Hadamard generators.

threefry2x32 is hand-rolled with uint32 jnp ops (shifts/xors/adds) because
``pltpu.prng_*`` has no interpret-mode lowering on CPU; a counter-based RNG is also
exactly what we want architecturally — tile (i, j) of the random sketch is a pure
function of (key, i, j), so grid order, multi-pod sharding, and checkpoint/restart all
reproduce identical sketches with zero coordination.
"""
from __future__ import annotations

import os

import numpy as np
import jax
import jax.numpy as jnp

_ROT = (13, 15, 26, 6, 17, 29, 16, 24)
_PARITY = np.uint32(0x1BD11BDA)


# ------------------------------------------------------------- interpret default


def default_interpret() -> bool:
    """Whether Pallas kernels should run in interpret mode on this backend.

    Mosaic lowering only exists for TPU; on CPU (tests, this container) and GPU the
    kernels must run interpreted. Every public kernel op takes ``interpret=None``
    meaning "resolve here", so compiled-vs-interpreted is decided in exactly one
    place instead of hard-coded per call site. ``REPRO_PALLAS_INTERPRET=0/1``
    overrides the autodetection (e.g. to force-interpret on TPU while debugging).
    """
    forced = os.environ.get("REPRO_PALLAS_INTERPRET", "").strip().lower()
    if forced in ("1", "true", "yes"):
        return True
    if forced in ("0", "false", "no"):
        return False
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    """``None`` -> backend autodetection; anything else is an explicit override."""
    return default_interpret() if interpret is None else bool(interpret)


def _rotl(x: jax.Array, r: int) -> jax.Array:
    return (x << np.uint32(r)) | (x >> np.uint32(32 - r))


def threefry2x32(k0: jax.Array, k1: jax.Array, c0: jax.Array, c1: jax.Array):
    """Standard 20-round Threefry-2x32. All args uint32 (broadcastable). Returns
    two uint32 streams with the shapes of (c0, c1)."""
    ks = (k0, k1, k0 ^ k1 ^ _PARITY)
    x0 = c0 + ks[0]
    x1 = c1 + ks[1]
    for block in range(5):
        for r in range(4):
            x0 = x0 + x1
            x1 = _rotl(x1, _ROT[(block % 2) * 4 + r])
            x1 = x1 ^ x0
        inj = block + 1
        x0 = x0 + ks[inj % 3]
        x1 = x1 + ks[(inj + 1) % 3] + np.uint32(inj)
    return x0, x1


def bits_to_open_unit(bits: jax.Array) -> jax.Array:
    """uint32 -> float32 in (0, 1), strictly positive so log() is finite."""
    return (bits.astype(jnp.float32) + 0.5) * jnp.float32(2.0**-32)


def counter_normal(k0, k1, c0, c1):
    """One standard normal per counter pair via threefry + Box-Muller (cos branch)."""
    b0, b1 = threefry2x32(k0, k1, c0, c1)
    u1 = bits_to_open_unit(b0)
    u2 = bits_to_open_unit(b1)
    r = jnp.sqrt(-2.0 * jnp.log(u1))
    return r * jnp.cos(jnp.float32(2.0 * np.pi) * u2)


def key_to_words(key: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Extract the two uint32 words of a jax PRNG key."""
    data = jax.random.key_data(key).astype(jnp.uint32).reshape(-1)
    return data[0], data[1]


def counter_rademacher(k0, k1, c0, c1, dtype=jnp.float32) -> jax.Array:
    """One ±1 sign per counter pair (low bit of the first threefry stream)."""
    b0, _ = threefry2x32(k0, k1, c0, c1)
    return (1 - 2 * (b0 & jnp.uint32(1)).astype(jnp.int32)).astype(dtype)


def sjlt_counter_params(k0, k1, row_idx: jax.Array, s: int, m: int, dtype=jnp.float32):
    """SJLT buckets/signs for the given *global* row indices, counter-derived.

    Row ``i``'s parameters are a pure function of ``(key, i)`` — independent of
    how rows are blocked or which shard asks — so blocked/streamed application and
    the Pallas kernel all see the same S. Returns ``(buckets, signs)`` of shape
    ``(len(row_idx), s)`` with signs scaled by 1/√s (``E[SᵀS] = I``). Bucket ids use
    a modulo reduction of the uint32 stream; the bias is ≤ m·2⁻³² per draw.
    """
    r = row_idx.astype(jnp.uint32)[:, None]
    t = jnp.arange(s, dtype=jnp.uint32)[None, :]
    b0, b1 = threefry2x32(k0, k1, r, t)
    buckets = (b0 % jnp.uint32(m)).astype(jnp.int32)
    signs = (1 - 2 * (b1 & jnp.uint32(1)).astype(jnp.int32)).astype(dtype)
    return buckets, signs * jnp.asarray(1.0 / np.sqrt(s), dtype)


def hadamard_matrix(k: int, dtype=jnp.float32) -> jax.Array:
    """Unnormalized k×k Hadamard (Sylvester): H[i,j] = (-1)^popcount(i&j), k pow2."""
    if k & (k - 1):
        raise ValueError(f"Hadamard size must be a power of two, got {k}")
    i = np.arange(k)[:, None] & np.arange(k)[None, :]
    signs = 1 - 2 * (np.bitwise_count(i.astype(np.uint64)).astype(np.int32) & 1)
    return jnp.asarray(signs, dtype=dtype)


def pad_axis_to(x: jax.Array, axis: int, target: int) -> jax.Array:
    if x.shape[axis] == target:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - x.shape[axis])
    return jnp.pad(x, pads)


def round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult
