"""Shared kernel utilities: in-kernel counter RNG and Hadamard generators.

threefry2x32 is hand-rolled with uint32 jnp ops (shifts/xors/adds) because
``pltpu.prng_*`` has no interpret-mode lowering on CPU; a counter-based RNG is also
exactly what we want architecturally — tile (i, j) of the random sketch is a pure
function of (key, i, j), so grid order, multi-pod sharding, and checkpoint/restart all
reproduce identical sketches with zero coordination.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp

from repro.utils import env as envcfg

_ROT = (13, 15, 26, 6, 17, 29, 16, 24)
_PARITY = np.uint32(0x1BD11BDA)
DEFAULT_ROUNDS = 20


# ------------------------------------------------------------- interpret default


def default_interpret() -> bool:
    """Whether Pallas kernels should run in interpret mode on this backend.

    Mosaic lowering only exists for TPU; on CPU (tests, this container) and GPU the
    kernels must run interpreted. Every public kernel op takes ``interpret=None``
    meaning "resolve here", so compiled-vs-interpreted is decided in exactly one
    place instead of hard-coded per call site. ``REPRO_PALLAS_INTERPRET=0/1``
    overrides the autodetection (e.g. to force-interpret on TPU while debugging).
    """
    forced = envcfg.read_bool("REPRO_PALLAS_INTERPRET")
    if forced is not None:
        return forced
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    """``None`` -> backend autodetection; anything else is an explicit override."""
    return default_interpret() if interpret is None else bool(interpret)


def _rotl(x: jax.Array, r: int) -> jax.Array:
    return (x << np.uint32(r)) | (x >> np.uint32(32 - r))


def rng_rounds() -> int:
    """Threefry round count for the *Gaussian* counter stream.

    ``REPRO_RNG_ROUNDS`` (default 20, must be a positive multiple of 4) selects a
    reduced-round Threefry variant for the RNG-bound Gaussian family — e.g. 8
    rounds cuts the per-entry uint work 2.5× while staying far above the 13-round
    cryptanalysis margin for *statistical* (non-cryptographic) use. Resolved at
    trace time: set it before the first jit of a Gaussian op (tests/benches use
    subprocesses). Sign-only streams (SJLT params, Rademacher, SRHT diagonals)
    always use the full :data:`DEFAULT_ROUNDS` — their cost is already ≤1 call
    per 32 entries, so there is nothing to win there.
    """
    return envcfg.read_int("REPRO_RNG_ROUNDS", DEFAULT_ROUNDS, positive=True, multiple_of=4)


def threefry2x32(
    k0: jax.Array, k1: jax.Array, c0: jax.Array, c1: jax.Array, *, rounds: int = DEFAULT_ROUNDS
):
    """Threefry-2x32 (20 rounds = the standard variant). All args uint32
    (broadcastable). Returns two uint32 streams with the shapes of (c0, c1)."""
    if rounds <= 0 or rounds % 4:
        raise ValueError(f"threefry rounds must be a positive multiple of 4, got {rounds}")
    ks = (k0, k1, k0 ^ k1 ^ _PARITY)
    x0 = c0 + ks[0]
    x1 = c1 + ks[1]
    for block in range(rounds // 4):
        for r in range(4):
            x0 = x0 + x1
            x1 = _rotl(x1, _ROT[(block % 2) * 4 + r])
            x1 = x1 ^ x0
        inj = block + 1
        x0 = x0 + ks[inj % 3]
        x1 = x1 + ks[(inj + 1) % 3] + np.uint32(inj)
    return x0, x1


def bits_to_open_unit(bits: jax.Array) -> jax.Array:
    """uint32 -> float32 in (0, 1), strictly positive so log() is finite."""
    return (bits.astype(jnp.float32) + 0.5) * jnp.float32(2.0**-32)


def counter_normal(k0, k1, c0, c1, *, rounds: int | None = None):
    """One standard normal per counter pair via threefry + Box-Muller (cos branch).

    ``rounds=None`` resolves :func:`rng_rounds` (the ``REPRO_RNG_ROUNDS`` knob) —
    this is the one RNG call sited on the Gaussian hot path, so the reduced-round
    variant is scoped here.
    """
    b0, b1 = threefry2x32(k0, k1, c0, c1, rounds=rng_rounds() if rounds is None else rounds)
    u1 = bits_to_open_unit(b0)
    u2 = bits_to_open_unit(b1)
    r = jnp.sqrt(-2.0 * jnp.log(u1))
    return r * jnp.cos(jnp.float32(2.0 * np.pi) * u2)


def key_to_words(key: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Extract the two uint32 words of a jax PRNG key."""
    data = jax.random.key_data(key).astype(jnp.uint32).reshape(-1)
    return data[0], data[1]


def keys_to_words(keys: jax.Array) -> jax.Array:
    """(q,)-batched PRNG keys -> (q, 2) uint32 words, row w == key_to_words(keys[w])."""
    return jax.random.key_data(keys).astype(jnp.uint32).reshape(keys.shape[0], 2)


def counter_rademacher(k0, k1, c0, c1, dtype=jnp.float32) -> jax.Array:
    """One ±1 sign per counter pair (low bit of the first threefry stream)."""
    b0, _ = threefry2x32(k0, k1, c0, c1)
    return (1 - 2 * (b0 & jnp.uint32(1)).astype(jnp.int32)).astype(dtype)


def packed_sign_words(k0, k1, rows: jax.Array, wcols: jax.Array) -> jax.Array:
    """One uint32 word of 32 packed Rademacher signs per (row, word-column) counter.

    The packed-sign contract shared by every consumer (jnp ``columns`` tiles, the
    Pallas Rademacher kernels): sign(i, j) = bit ``j % 32`` of
    ``threefry(key, i, j // 32)[0]`` — a pure function of (key, i, j), so any
    tiling / blocking / sharding regenerates the identical S. One threefry call
    yields 32 entries, versus one call *plus* Box-Muller per entry for the
    Gaussian stream — this is the whole RNG-bound-path fix.
    """
    b0, _ = threefry2x32(k0, k1, rows, wcols)
    return b0


def unpack_signs(words: jax.Array, bitpos: jax.Array, dtype=jnp.float32) -> jax.Array:
    """±1 from bit ``bitpos`` of each uint32 in ``words`` (shapes broadcast)."""
    bits = (words >> bitpos.astype(jnp.uint32)) & jnp.uint32(1)
    return (1 - 2 * bits.astype(jnp.int32)).astype(dtype)


def packed_sign_tile(k0, k1, row0, col0, nrows: int, ncols: int, dtype=jnp.float32) -> jax.Array:
    """Aligned packed-contract sign tile: ``col0`` (traced ok) and ``ncols`` must be
    multiples of 32 — the Pallas-kernel fast path (no covering slack, no slice)."""
    nw = ncols // 32
    rows = jnp.uint32(row0) + jax.lax.broadcasted_iota(jnp.uint32, (nrows, nw), 0)
    wcols = jnp.uint32(col0) // jnp.uint32(32) + jax.lax.broadcasted_iota(
        jnp.uint32, (nrows, nw), 1
    )
    words = jnp.repeat(packed_sign_words(k0, k1, rows, wcols), 32, axis=1)
    bitpos = jax.lax.broadcasted_iota(jnp.uint32, (nrows, ncols), 1) % jnp.uint32(32)
    return unpack_signs(words, bitpos, dtype)


def counter_rademacher_block(
    k0, k1, row0, col0, nrows: int, ncols: int, dtype=jnp.float32
) -> jax.Array:
    """(nrows, ncols) tile of ±1 packed-contract signs at (possibly traced) offsets.

    Draws the covering word range [col0//32, …] (``ncols // 32 + 2`` words per row
    — at most one wasted word each side for unaligned col0), unpacks, and
    dynamic-slices the requested window, so arbitrary ``block_rows`` streaming
    reproduces the aligned Pallas-kernel tiles bit-for-bit.
    """
    c0 = jnp.uint32(col0)
    w0 = c0 // jnp.uint32(32)
    nw = ncols // 32 + 2
    rows = jnp.uint32(row0) + jax.lax.broadcasted_iota(jnp.uint32, (nrows, nw), 0)
    wcols = w0 + jax.lax.broadcasted_iota(jnp.uint32, (nrows, nw), 1)
    words = jnp.repeat(packed_sign_words(k0, k1, rows, wcols), 32, axis=1)
    bitpos = jax.lax.broadcasted_iota(jnp.uint32, (nrows, nw * 32), 1) % jnp.uint32(32)
    signs = unpack_signs(words, bitpos, dtype)
    return jax.lax.dynamic_slice_in_dim(signs, (c0 - w0 * jnp.uint32(32)).astype(jnp.int32), ncols, axis=1)


def sjlt_counter_params(k0, k1, row_idx: jax.Array, s: int, m: int, dtype=jnp.float32):
    """SJLT buckets/signs for the given *global* row indices, counter-derived.

    Row ``i``'s parameters are a pure function of ``(key, i)`` — independent of
    how rows are blocked or which shard asks — so blocked/streamed application and
    the Pallas kernel all see the same S. Returns ``(buckets, signs)`` of shape
    ``(len(row_idx), s)`` with signs scaled by 1/√s (``E[SᵀS] = I``). Bucket ids use
    a modulo reduction of the uint32 stream; the bias is ≤ m·2⁻³² per draw.
    """
    r = row_idx.astype(jnp.uint32)[:, None]
    t = jnp.arange(s, dtype=jnp.uint32)[None, :]
    b0, b1 = threefry2x32(k0, k1, r, t)
    buckets = (b0 % jnp.uint32(m)).astype(jnp.int32)
    signs = (1 - 2 * (b1 & jnp.uint32(1)).astype(jnp.int32)).astype(dtype)
    return buckets, signs * jnp.asarray(1.0 / np.sqrt(s), dtype)


@functools.lru_cache(maxsize=None)
def _hadamard_cached(k: int, dtype_name: str) -> np.ndarray:
    # Host-side cache: a device jnp array must NOT be cached here, or the first
    # call under a jit trace would leak its tracer into every later trace.
    i = np.arange(k)[:, None] & np.arange(k)[None, :]
    signs = 1 - 2 * (np.bitwise_count(i.astype(np.uint64)).astype(np.int32) & 1)
    return np.asarray(signs, dtype=np.dtype(dtype_name))


def hadamard_matrix(k: int, dtype=jnp.float32) -> jax.Array:
    """Unnormalized k×k Hadamard (Sylvester): H[i,j] = (-1)^popcount(i&j), k pow2.

    Cached on (k, dtype): every SRHT apply/gram trace uses the same one or two
    factor matrices, and the O(k²) popcount construction was being repaid per
    trace. The conversion per call is a cheap constant embed / transfer.
    """
    if k & (k - 1):
        raise ValueError(f"Hadamard size must be a power of two, got {k}")
    return jnp.asarray(_hadamard_cached(k, np.dtype(dtype).name))


def pad_axis_to(x: jax.Array, axis: int, target: int) -> jax.Array:
    if x.shape[axis] == target:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - x.shape[axis])
    return jnp.pad(x, pads)


def round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult
