from repro.kernels.sjlt import ops, ref
from repro.kernels.sjlt.ops import sjlt_apply, sjlt_sketch
