"""Public SJLT ops: parameter generation + padded kernel dispatch."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.sjlt import gram as K_gram
from repro.kernels.sjlt import kernel as K
from repro.kernels.sjlt import ref as R

BLOCK_M = 512
BLOCK_N = 256
BLOCK_D = 256


def sjlt_params(key: jax.Array, n: int, s: int, m: int, dtype=jnp.float32):
    """Bucket indices and ±1/√s signs — the (only) randomness of the sketch.

    Counter-derived per *global* row index (``common.sjlt_counter_params``), the
    identical draw ``repro.core.operators.SJLTOp`` uses, so the kernel and the
    pure-jnp path see the same S for the same key — and so any row block's
    parameters can be regenerated independently when streaming.
    """
    k0, k1 = common.key_to_words(key)
    return common.sjlt_counter_params(k0, k1, jnp.arange(n), s, m, dtype=dtype)


@functools.partial(jax.jit, static_argnames=("m", "interpret", "use_ref"))
def sjlt_apply(
    A: jax.Array,
    buckets: jax.Array,
    signs: jax.Array,
    m: int,
    *,
    interpret: bool | None = None,
    use_ref: bool = False,
) -> jax.Array:
    """S @ A for the SJLT defined by (buckets, signs). A: (n, d) -> (m, d)."""
    interpret = common.resolve_interpret(interpret)
    if use_ref:
        return R.sjlt_apply(A, buckets, signs, m)
    n, d = A.shape
    s = buckets.shape[1]
    dtype = A.dtype

    bm = min(BLOCK_M, common.round_up(m, 128))
    bn = min(BLOCK_N, common.round_up(n, 8))
    bd = min(BLOCK_D, common.round_up(d, 128))
    m_pad = common.round_up(m, bm)
    n_pad = common.round_up(n, bn)
    d_pad = common.round_up(d, bd)

    Af = common.pad_axis_to(common.pad_axis_to(A.astype(jnp.float32), 0, n_pad), 1, d_pad)
    # Padded (fictitious) input rows must not contribute: route them to bucket -1,
    # which no m-tile's local iota can match.
    buckets_p = common.pad_axis_to(buckets + 1, 0, n_pad) - 1
    signs_p = common.pad_axis_to(signs.astype(jnp.float32), 0, n_pad)

    out = K.sjlt_tiles(
        Af, buckets_p, signs_p, m_pad, block_m=bm, block_n=bn, block_d=bd, interpret=interpret
    )
    return out[:m, :d].astype(dtype)


@functools.partial(jax.jit, static_argnames=("m", "interpret"))
def sjlt_gram(
    A: jax.Array,
    buckets: jax.Array,
    signs: jax.Array,
    m: int,
    *,
    interpret: bool | None = None,
) -> jax.Array:
    """G = (SA)ᵀ(SA) ∈ R^{d×d} in one fused pass over A (SA never hits HBM)."""
    interpret = common.resolve_interpret(interpret)
    n, d = A.shape
    s = buckets.shape[1]

    bn = min(BLOCK_N, common.round_up(n, 8))
    n_pad = common.round_up(n, bn)
    d_pad = common.round_up(d, 128)
    m_pad = common.round_up(m, 8)

    Af = common.pad_axis_to(common.pad_axis_to(A.astype(jnp.float32), 0, n_pad), 1, d_pad)
    # Padded (fictitious) rows: bucket -1 matches no accumulator column, sign 0.
    buckets_p = common.pad_axis_to(buckets + 1, 0, n_pad) - 1
    signs_p = common.pad_axis_to(signs.astype(jnp.float32), 0, n_pad)

    G = K_gram.sjlt_gram_tiles(
        Af, buckets_p, signs_p, m_pad, block_n=bn, interpret=interpret
    )
    return G[:d, :d]


@functools.partial(jax.jit, static_argnames=("m", "interpret"))
def sjlt_gram_multi(
    A: jax.Array,
    buckets: jax.Array,
    signs: jax.Array,
    m: int,
    *,
    interpret: bool | None = None,
) -> jax.Array:
    """All q workers' ``G_k`` for per-worker SJLT params from ONE launch.

    ``buckets``/``signs``: (q, n, s). Returns (q, d, d) f32; worker slice w is
    bitwise-identical to ``sjlt_gram(A, buckets[w], signs[w], m)``.
    """
    interpret = common.resolve_interpret(interpret)
    n, d = A.shape

    bn = min(BLOCK_N, common.round_up(n, 8))
    n_pad = common.round_up(n, bn)
    d_pad = common.round_up(d, 128)
    m_pad = common.round_up(m, 8)

    Af = common.pad_axis_to(common.pad_axis_to(A.astype(jnp.float32), 0, n_pad), 1, d_pad)
    # Padded (fictitious) rows: bucket -1 matches no accumulator column, sign 0.
    buckets_p = common.pad_axis_to(buckets + 1, 1, n_pad) - 1
    signs_p = common.pad_axis_to(signs.astype(jnp.float32), 1, n_pad)

    G = K_gram.sjlt_gram_tiles_multi(
        Af, buckets_p, signs_p, m_pad, block_n=bn, interpret=interpret
    )
    return G[:, :d, :d]


def sjlt_sketch(
    key: jax.Array, A: jax.Array, m: int, *, s: int = 4, interpret: bool | None = None
) -> jax.Array:
    """Draw SJLT params from ``key`` and apply via the kernel."""
    buckets, signs = sjlt_params(key, A.shape[0], s, m, dtype=jnp.float32)
    return sjlt_apply(A, buckets, signs, m, interpret=interpret)


def flops_and_bytes(n: int, d: int, m: int, s: int) -> dict:
    """Structural cost: the kernel is a (n·s, m)×(n·s, d) accumulation walked in
    m-tiles; useful-work view is 2·n·s·d MACs (each nonzero touches d values)."""
    return {"flops": 2 * n * s * d, "bytes": 4 * (n * d + m * d + n * s * 2)}
