"""Pure-jnp oracle for the SJLT (sparse JL / CountSketch) apply."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sjlt_apply(A: jax.Array, buckets: jax.Array, signs: jax.Array, m: int) -> jax.Array:
    """(SA) where S has, for input coordinate i, nonzeros ``signs[i, t]`` in rows
    ``buckets[i, t]`` (t < s). A: (n, d); buckets/signs: (n, s). Returns (m, d)."""
    n, s = buckets.shape
    vals = signs[..., None] * A[:, None, :]              # (n, s, d)
    flat = vals.reshape(n * s, A.shape[1])
    return jax.ops.segment_sum(flat, buckets.reshape(-1), num_segments=m)
