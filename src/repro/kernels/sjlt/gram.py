"""Pallas TPU kernel: fused SJLT sketch→Gram — G = (SA)ᵀ(SA) in ONE pass over A.

Same single-pass structure as the Gaussian gram kernel (grid over row tiles of A, an
(m, d) VMEM scratch accumulator that persists across the sequential grid, Gram formed
once at the final step), but the S tile is the SJLT one-hot slice built in registers
from the counter-derived bucket/sign parameters — the identical construction the
apply kernel uses, so the fused Gram is the Gram of exactly that sketch.

Per n-tile:  acc += one_hot(bucketsᵀ) · (signs ⊙ A-replicated)   (scatter as matmul)
Final step:  G = accᵀ · acc

Padded input rows are routed to bucket −1 by the caller (no local column matches) and
carry zero signs, so they contribute nothing; accumulator rows beyond the true m are
never addressed because bucket ids live in [0, m).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def sjlt_gram_tiles(
    A: jax.Array,
    buckets: jax.Array,
    signs: jax.Array,
    m_pad: int,
    *,
    block_n: int,
    interpret: bool = True,
) -> jax.Array:
    """G = (SA)ᵀ(SA) for the SJLT defined by (buckets, signs). A: (n_pad, d_pad);
    buckets/signs: (n_pad, s). Returns (d_pad, d_pad) f32."""
    n, d = A.shape
    s = buckets.shape[1]
    n_tiles = n // block_n

    def kernel(b_ref, s_ref, a_ref, o_ref, acc_ref):
        ni = pl.program_id(0)

        @pl.when(ni == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        buckets_blk = b_ref[...]
        signs_blk = s_ref[...]
        a = a_ref[...]
        nb, ss = buckets_blk.shape
        cols = jax.lax.broadcasted_iota(jnp.int32, (nb * ss, m_pad), 1)
        flat = buckets_blk.reshape(nb * ss, 1)
        onehot = jnp.where(cols == flat, signs_blk.reshape(nb * ss, 1), 0.0).astype(a.dtype)
        a_rep = jnp.repeat(a, ss, axis=0)
        acc_ref[...] += jax.lax.dot_general(
            onehot, a_rep, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )

        @pl.when(ni == n_tiles - 1)
        def _finish():
            acc = acc_ref[...]
            o_ref[...] = jax.lax.dot_general(
                acc, acc, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
            )

    return pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((block_n, s), lambda ni: (ni, 0)),
            pl.BlockSpec((block_n, s), lambda ni: (ni, 0)),
            pl.BlockSpec((block_n, d), lambda ni: (ni, 0)),
        ],
        out_specs=pl.BlockSpec((d, d), lambda ni: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((d, d), jnp.float32),
        scratch_shapes=[pltpu.VMEM((m_pad, d), jnp.float32)],
        interpret=interpret,
    )(buckets, signs, A)


def sjlt_gram_tiles_multi(
    A: jax.Array,
    buckets: jax.Array,
    signs: jax.Array,
    m_pad: int,
    *,
    block_n: int,
    interpret: bool = True,
) -> jax.Array:
    """All q workers' SJLT Grams from ONE launch / ONE read of A.

    ``buckets``/``signs``: (q, n_pad, s) — per-worker counter-derived parameters
    (tiny: s ints per row vs d floats of A). The A tile *and* its s-replicated
    copy are built once per grid step and shared across the statically-unrolled
    worker loop; only the one-hot scatter matmul is per-worker. Per worker the op
    sequence matches :func:`sjlt_gram_tiles`, so output slice w is bitwise equal
    to a single launch with that worker's parameters.
    """
    n, d = A.shape
    q, _, s = buckets.shape
    n_tiles = n // block_n

    def kernel(b_ref, s_ref, a_ref, o_ref, acc_ref):
        ni = pl.program_id(0)

        @pl.when(ni == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        a = a_ref[...]
        nb = a.shape[0]
        a_rep = jnp.repeat(a, s, axis=0)  # shared across all q workers
        cols = jax.lax.broadcasted_iota(jnp.int32, (nb * s, m_pad), 1)
        for w in range(q):
            flat = b_ref[w].reshape(nb * s, 1)
            onehot = jnp.where(cols == flat, s_ref[w].reshape(nb * s, 1), 0.0).astype(a.dtype)
            acc_ref[w] += jax.lax.dot_general(
                onehot, a_rep, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
            )

        @pl.when(ni == n_tiles - 1)
        def _finish():
            for w in range(q):
                acc = acc_ref[w]
                o_ref[w] = jax.lax.dot_general(
                    acc, acc, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
                )

    return pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((q, block_n, s), lambda ni: (0, ni, 0)),
            pl.BlockSpec((q, block_n, s), lambda ni: (0, ni, 0)),
            pl.BlockSpec((block_n, d), lambda ni: (ni, 0)),
        ],
        out_specs=pl.BlockSpec((q, d, d), lambda ni: (0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((q, d, d), jnp.float32),
        scratch_shapes=[pltpu.VMEM((q, m_pad, d), jnp.float32)],
        interpret=interpret,
    )(buckets, signs, A)
