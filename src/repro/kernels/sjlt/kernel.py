"""Pallas TPU kernel: SJLT apply as accumulated one-hot matmuls.

GPU SJLT is an atomic scatter-add — the worst possible op for a TPU. The adaptation:
for every row block of A we build the (rows·s, MB) slice of Sᵀ *in registers* from the
bucket indices (iota compare — no HBM traffic for S), and contract it with the row
block on the MXU:

    out[mb, db] += one_hot(buckets_blk − m_lo)ᵀ · (signs ⊙ A_blk-replicated)

The grid is (m_tiles, d_tiles, n_tiles) with the n axis innermost; the output tile is
revisited across n steps and accumulated in place (zeroed at n_step == 0). Scatter
becomes dense compute: n·s·m MACs, which for s ≤ 8 and m ≪ n is tiny next to the
memory streaming of A itself — i.e. the op stays bandwidth-bound, now without any
serialization hazard.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def sjlt_tiles(
    A: jax.Array,
    buckets: jax.Array,
    signs: jax.Array,
    m_pad: int,
    *,
    block_m: int,
    block_n: int,
    block_d: int,
    interpret: bool = True,
) -> jax.Array:
    """A: (n_pad, d_pad); buckets/signs: (n_pad, s). All dims divisible by blocks."""
    n, d = A.shape
    s = buckets.shape[1]
    grid = (m_pad // block_m, d // block_d, n // block_n)

    def kernel(b_ref, s_ref, a_ref, o_ref):
        # Shift global bucket ids into this m-tile's local range; the iota compare
        # then yields the (nb·s, block_m) slice of Sᵀ without any HBM traffic for S.
        mi = pl.program_id(0)
        ni = pl.program_id(2)

        @pl.when(ni == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        buckets_blk = b_ref[...] - mi * block_m
        signs_blk = s_ref[...]
        a = a_ref[...]
        nb, ss = buckets_blk.shape
        cols = jax.lax.broadcasted_iota(jnp.int32, (nb * ss, block_m), 1)
        flat = buckets_blk.reshape(nb * ss, 1)
        onehot = jnp.where(cols == flat, signs_blk.reshape(nb * ss, 1), 0.0).astype(a.dtype)
        a_rep = jnp.repeat(a, ss, axis=0)
        contrib = jax.lax.dot_general(
            onehot, a_rep, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        o_ref[...] += contrib.astype(o_ref.dtype)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, s), lambda mi, di, ni: (ni, 0)),
            pl.BlockSpec((block_n, s), lambda mi, di, ni: (ni, 0)),
            pl.BlockSpec((block_n, block_d), lambda mi, di, ni: (ni, di)),
        ],
        out_specs=pl.BlockSpec((block_m, block_d), lambda mi, di, ni: (mi, di)),
        out_shape=jax.ShapeDtypeStruct((m_pad, d), jnp.float32),
        interpret=interpret,
    )(buckets, signs, A)
