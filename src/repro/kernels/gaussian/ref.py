"""Pure-jnp oracle for the RNG-fused Gaussian sketch.

Materializes the same counter-derived S the kernel generates tile-by-tile (same
threefry2x32 + Box-Muller stream, element (i, j) keyed by counters (i, j)), then does
a plain matmul. The kernel must match this to float precision.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.kernels import common


def sketch_matrix(key: jax.Array, m: int, n: int) -> jax.Array:
    """The full S ∈ R^{m×n} with entries N(0, 1/m) from the counter stream."""
    k0, k1 = common.key_to_words(key)
    rows = jnp.arange(m, dtype=jnp.uint32)[:, None]
    cols = jnp.arange(n, dtype=jnp.uint32)[None, :]
    z = common.counter_normal(k0, k1, jnp.broadcast_to(rows, (m, n)), jnp.broadcast_to(cols, (m, n)))
    return z * jnp.float32(1.0 / math.sqrt(m))


def gaussian_sketch(key: jax.Array, A: jax.Array, m: int) -> jax.Array:
    S = sketch_matrix(key, m, A.shape[0])
    return (S @ A.astype(jnp.float32)).astype(A.dtype)
