"""Public RNG-fused Gaussian sketch op."""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.gaussian import gram as K_gram
from repro.kernels.gaussian import kernel as K

BLOCK_M = 256
BLOCK_N = 512
BLOCK_D = 256


@functools.partial(jax.jit, static_argnames=("m", "interpret"))
def gaussian_sketch(key: jax.Array, A: jax.Array, m: int, *, interpret: bool | None = None) -> jax.Array:
    """S @ A with S ~ N(0, 1/m)^{m×n} generated inside the kernel. A: (n, d)."""
    interpret = common.resolve_interpret(interpret)
    orig_ndim = A.ndim
    if A.ndim == 1:
        A = A[:, None]
    n, d = A.shape
    dtype = A.dtype

    bm = min(BLOCK_M, common.round_up(m, 8))
    bn = min(BLOCK_N, common.round_up(n, 8))
    bd = min(BLOCK_D, common.round_up(d, 128))
    m_pad = common.round_up(m, bm)
    n_pad = common.round_up(n, bn)
    d_pad = common.round_up(d, bd)

    Af = common.pad_axis_to(common.pad_axis_to(A.astype(jnp.float32), 0, n_pad), 1, d_pad)
    k0, k1 = common.key_to_words(key)
    key_words = jnp.stack([k0, k1])

    out = K.gaussian_tiles(
        Af,
        key_words,
        m_pad,
        n,
        block_m=bm,
        block_n=bn,
        block_d=bd,
        inv_sqrt_m=1.0 / math.sqrt(m),
        interpret=interpret,
    )
    out = out[:m, :d].astype(dtype)
    return out[:, 0] if orig_ndim == 1 else out


@functools.partial(jax.jit, static_argnames=("m", "interpret"))
def gaussian_gram(key: jax.Array, A: jax.Array, m: int, *, interpret: bool | None = None) -> jax.Array:
    """G = (SA)ᵀ(SA) ∈ R^{d×d} in one fused pass — S and SA never touch HBM.

    Pass ``A = [data | b]`` to get the Gram and right-hand side of the sketched
    normal equations from a single streaming of the data (callers slice G and c).
    """
    interpret = common.resolve_interpret(interpret)
    n, d = A.shape
    bn = min(BLOCK_N, common.round_up(n, 8))
    n_pad = common.round_up(n, bn)
    d_pad = common.round_up(d, 128)
    m_pad = common.round_up(m, 8)

    Af = common.pad_axis_to(common.pad_axis_to(A.astype(jnp.float32), 0, n_pad), 1, d_pad)
    k0, k1 = common.key_to_words(key)
    key_words = jnp.stack([k0, k1])

    G = K_gram.gaussian_gram_tiles(
        Af,
        key_words,
        m,
        m_pad,
        block_n=bn,
        inv_sqrt_m=1.0 / math.sqrt(m),
        interpret=interpret,
    )
    return G[:d, :d]


@functools.partial(jax.jit, static_argnames=("m", "interpret"))
def gaussian_gram_multi(
    keys: jax.Array, A: jax.Array, m: int, *, interpret: bool | None = None
) -> jax.Array:
    """All q workers' ``G_k = (S_kA)ᵀ(S_kA)`` from ONE launch / ONE read of A.

    ``keys``: (q,)-batched PRNG keys (``prng.worker_keys``). Returns (q, d, d)
    f32, worker slice w bitwise-identical to ``gaussian_gram(keys[w], A, m)``
    (same padding, same tile walk, same per-worker op sequence).
    """
    interpret = common.resolve_interpret(interpret)
    n, d = A.shape
    bn = min(BLOCK_N, common.round_up(n, 8))
    n_pad = common.round_up(n, bn)
    d_pad = common.round_up(d, 128)
    m_pad = common.round_up(m, 8)

    Af = common.pad_axis_to(common.pad_axis_to(A.astype(jnp.float32), 0, n_pad), 1, d_pad)
    key_words = common.keys_to_words(keys)

    G = K_gram.gaussian_gram_tiles_multi(
        Af,
        key_words,
        m,
        m_pad,
        block_n=bn,
        inv_sqrt_m=1.0 / math.sqrt(m),
        interpret=interpret,
    )
    return G[:, :d, :d]


@functools.partial(jax.jit, static_argnames=("n", "interpret"))
def gaussian_adjoint(key: jax.Array, Y: jax.Array, n: int, *, interpret: bool | None = None) -> jax.Array:
    """Sᵀ @ Y with S ~ N(0, 1/m)^{m×n} regenerated in-core. Y: (m, k) or (m,)."""
    interpret = common.resolve_interpret(interpret)
    orig_ndim = Y.ndim
    if Y.ndim == 1:
        Y = Y[:, None]
    m, k = Y.shape
    dtype = Y.dtype

    bm = min(BLOCK_M, common.round_up(m, 8))
    bn = min(BLOCK_N, common.round_up(n, 8))
    bk = min(BLOCK_D, common.round_up(k, 128))
    m_pad = common.round_up(m, bm)
    n_pad = common.round_up(n, bn)
    k_pad = common.round_up(k, bk)

    Yf = common.pad_axis_to(common.pad_axis_to(Y.astype(jnp.float32), 0, m_pad), 1, k_pad)
    k0, k1 = common.key_to_words(key)
    key_words = jnp.stack([k0, k1])

    out = K_gram.gaussian_adjoint_tiles(
        Yf,
        key_words,
        n_pad,
        block_n=bn,
        block_m=bm,
        block_k=bk,
        inv_sqrt_m=1.0 / math.sqrt(m),
        interpret=interpret,
    )
    out = out[:n, :k].astype(dtype)
    return out[:, 0] if orig_ndim == 1 else out


def flops_and_bytes(n: int, d: int, m: int) -> dict:
    """Structural roofline terms: matmul FLOPs + fused-RNG generation, but only
    O((n+m)·d) HBM bytes — S never exists in memory."""
    rng_flops_per_elem = 60  # ~20 rounds × 3 uint ops (adds/xors/rots counted as 1)
    return {
        "flops": 2 * m * n * d + rng_flops_per_elem * m * n,
        "bytes": 4 * (n * d + m * d),
        "bytes_materialized": 4 * (m * n + n * d + m * d),
    }
