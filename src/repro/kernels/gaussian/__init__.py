from repro.kernels.gaussian import ops, ref
from repro.kernels.gaussian.ops import gaussian_sketch
