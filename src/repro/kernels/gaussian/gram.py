"""Pallas TPU kernel: fused Gaussian sketch→Gram — G = (SA)ᵀ(SA) in ONE pass over A.

The sketch-and-solve hot loop only ever consumes ``SA`` through its Gram matrix
``G = (SA)ᵀ(SA)`` and right-hand side ``c = (SA)ᵀ(Sb)`` (the m×d problem is solved by
Cholesky on G). Materializing SA first means a full HBM round-trip of an (m, d) array
per worker plus a second kernel launch for the Gram; materializing S itself is O(m·n)
bytes of pure reproducible noise.

This kernel does the whole chain in one streamed pass: the grid walks row tiles of A,
each (m, block_n) tile of S is generated in VMEM from the counter RNG (same stream as
``GaussianOp.columns`` / the apply kernel), contracted with the A tile on the MXU into
an (m, d) VMEM scratch accumulator — scratch persists across the sequential TPU grid —
and only at the final grid step is the tiny (d, d) Gram contraction formed and written
out. HBM traffic: read A once, write d² floats. S and SA never exist in HBM.

Sketching ``[A | b]`` jointly yields G and c from the same pass (callers slice).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import common


def gaussian_gram_tiles(
    A: jax.Array,
    key_words: jax.Array,
    m: int,
    m_pad: int,
    *,
    block_n: int,
    inv_sqrt_m: float,
    interpret: bool = True,
) -> jax.Array:
    """G = (SA)ᵀ(SA) with S ~ N(0, 1/m) generated in-core. A: (n_pad, d_pad), both
    padded dims zero-filled; returns (d_pad, d_pad) f32. Rows of S beyond ``m``
    (padding to the sublane multiple) are masked to zero so they never enter G."""
    n, d = A.shape
    n_tiles = n // block_n

    def kernel(kw_ref, a_ref, o_ref, acc_ref):
        ni = pl.program_id(0)

        @pl.when(ni == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        k0 = kw_ref[0]
        k1 = kw_ref[1]
        rows = jax.lax.broadcasted_iota(jnp.uint32, (m_pad, block_n), 0)
        cols = (ni * block_n).astype(jnp.uint32) + jax.lax.broadcasted_iota(
            jnp.uint32, (m_pad, block_n), 1
        )
        s_tile = common.counter_normal(k0, k1, rows, cols) * jnp.float32(inv_sqrt_m)
        s_tile = jnp.where(rows < jnp.uint32(m), s_tile, 0.0)
        acc_ref[...] += jnp.dot(s_tile, a_ref[...], preferred_element_type=jnp.float32)

        @pl.when(ni == n_tiles - 1)
        def _finish():
            acc = acc_ref[...]
            o_ref[...] = jax.lax.dot_general(
                acc, acc, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
            )

    return pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((2,), lambda ni: (0,)),
            pl.BlockSpec((block_n, d), lambda ni: (ni, 0)),
        ],
        out_specs=pl.BlockSpec((d, d), lambda ni: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((d, d), jnp.float32),
        scratch_shapes=[pltpu.VMEM((m_pad, d), jnp.float32)],
        interpret=interpret,
    )(key_words, A)


def gaussian_gram_tiles_multi(
    A: jax.Array,
    key_words: jax.Array,
    m: int,
    m_pad: int,
    *,
    block_n: int,
    inv_sqrt_m: float,
    interpret: bool = True,
) -> jax.Array:
    """All q workers' Grams from ONE kernel launch / ONE read of A.

    ``key_words``: (q, 2) uint32 — one counter key per worker. The grid still
    walks row tiles of A, but each step contracts the tile against all q workers'
    S tiles (statically unrolled: q is a trace-time constant, so every scratch
    access is static — no dynamic VMEM indexing) into a (q, m_pad, d) scratch.
    The A tile's index map depends only on the grid step, so it is fetched once
    per step and reused across workers — the per-worker launch loop read A q
    times. Per worker the op sequence (same tile order, same dot shapes) is
    identical to :func:`gaussian_gram_tiles`, so the (d_pad, d_pad) slices of the
    (q, d_pad, d_pad) output are bitwise equal to q single launches.

    VMEM budget: scratch is q·m_pad·d·4 bytes (q=8, m=1024, d=257-pad → ~8 MiB on
    the acceptance shape) — callers chunk q when the budget doesn't fit.
    """
    n, d = A.shape
    q = key_words.shape[0]
    n_tiles = n // block_n

    def kernel(kw_ref, a_ref, o_ref, acc_ref):
        ni = pl.program_id(0)

        @pl.when(ni == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        a = a_ref[...]
        rows = jax.lax.broadcasted_iota(jnp.uint32, (m_pad, block_n), 0)
        cols = (ni * block_n).astype(jnp.uint32) + jax.lax.broadcasted_iota(
            jnp.uint32, (m_pad, block_n), 1
        )
        for w in range(q):  # static unroll: q accumulators, one read of A
            s_tile = common.counter_normal(kw_ref[w, 0], kw_ref[w, 1], rows, cols) * jnp.float32(
                inv_sqrt_m
            )
            s_tile = jnp.where(rows < jnp.uint32(m), s_tile, 0.0)
            acc_ref[w] += jnp.dot(s_tile, a, preferred_element_type=jnp.float32)

        @pl.when(ni == n_tiles - 1)
        def _finish():
            for w in range(q):
                acc = acc_ref[w]
                o_ref[w] = jax.lax.dot_general(
                    acc, acc, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
                )

    return pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((q, 2), lambda ni: (0, 0)),
            pl.BlockSpec((block_n, d), lambda ni: (ni, 0)),
        ],
        out_specs=pl.BlockSpec((q, d, d), lambda ni: (0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((q, d, d), jnp.float32),
        scratch_shapes=[pltpu.VMEM((q, m_pad, d), jnp.float32)],
        interpret=interpret,
    )(key_words, A)


def gaussian_adjoint_tiles(
    Y: jax.Array,
    key_words: jax.Array,
    n_pad: int,
    *,
    block_n: int,
    block_m: int,
    block_k: int,
    inv_sqrt_m: float,
    interpret: bool = True,
) -> jax.Array:
    """out = Sᵀ @ Y with S generated in-core (the missing Gaussian adjoint kernel).

    Y: (m_pad, k_pad), zero-padded below the true m so padded sketch rows contribute
    nothing. Grid (n_tiles, k_tiles, m_tiles) with m innermost: the (block_n, block_k)
    output tile is revisited and accumulated across m steps, exactly mirroring the
    forward kernel's n-accumulation. S tiles use the same (key, i, j) counter stream
    as the forward pass, so adjoint(apply(x)) sees one consistent S.
    """
    m, k = Y.shape
    grid = (n_pad // block_n, k // block_k, m // block_m)

    def kernel(kw_ref, y_ref, o_ref):
        ni = pl.program_id(0)
        mi = pl.program_id(2)

        @pl.when(mi == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        k0 = kw_ref[0]
        k1 = kw_ref[1]
        rows = (mi * block_m).astype(jnp.uint32) + jax.lax.broadcasted_iota(
            jnp.uint32, (block_m, block_n), 0
        )
        cols = (ni * block_n).astype(jnp.uint32) + jax.lax.broadcasted_iota(
            jnp.uint32, (block_m, block_n), 1
        )
        s_tile = common.counter_normal(k0, k1, rows, cols) * jnp.float32(inv_sqrt_m)
        contrib = jax.lax.dot_general(
            s_tile, y_ref[...], (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        o_ref[...] += contrib

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((2,), lambda ni, ki, mi: (0,)),
            pl.BlockSpec((block_m, block_k), lambda ni, ki, mi: (mi, ki)),
        ],
        out_specs=pl.BlockSpec((block_n, block_k), lambda ni, ki, mi: (ni, ki)),
        out_shape=jax.ShapeDtypeStruct((n_pad, k), jnp.float32),
        interpret=interpret,
    )(key_words, Y)
