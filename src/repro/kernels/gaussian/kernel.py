"""Pallas TPU kernel: Gaussian sketch with the random matrix generated in-core.

The naive S·A reads m·n Gaussian entries from HBM that are pure, reproducible noise.
This kernel never stores S: each (block_m × block_n) tile is generated in VMEM/VREGs
from a counter-based threefry2x32 (element (i,j) ← counters (i,j), so the stream is
independent of grid order and of how the work is sharded across chips), pushed through
Box-Muller, and immediately contracted with the matching A tile on the MXU.

    HBM bytes: O(n·d + m·d)   (vs O(m·n + n·d + m·d) for materialize-then-matmul)

For the paper's regime (m ≈ 5d, n ≫ m) the materialized version moves ~m/d ≈ 5× the
bytes of A itself; fusing the RNG turns the Gaussian sketch from bandwidth-dominated
to the same O(n·d) streaming cost as sampling-based sketches, while keeping MXU
utilization (the tile matmul) as the compute term.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels import common


def gaussian_tiles(
    A: jax.Array,
    key_words: jax.Array,
    m_pad: int,
    n_valid: int,
    *,
    block_m: int,
    block_n: int,
    block_d: int,
    inv_sqrt_m: float,
    interpret: bool = True,
) -> jax.Array:
    """out = S @ A. A: (n_pad, d_pad); key_words: (2,) uint32. Rows of A beyond
    n_valid are zero-padded so their (well-defined) S entries contribute nothing."""
    n, d = A.shape
    grid = (m_pad // block_m, d // block_d, n // block_n)

    def kernel(kw_ref, a_ref, o_ref):
        mi = pl.program_id(0)
        ni = pl.program_id(2)

        @pl.when(ni == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        k0 = kw_ref[0]
        k1 = kw_ref[1]
        row0 = (mi * block_m).astype(jnp.uint32)
        col0 = (ni * block_n).astype(jnp.uint32)
        rows = row0 + jax.lax.broadcasted_iota(jnp.uint32, (block_m, block_n), 0)
        cols = col0 + jax.lax.broadcasted_iota(jnp.uint32, (block_m, block_n), 1)
        s_tile = common.counter_normal(k0, k1, rows, cols) * jnp.float32(inv_sqrt_m)
        a = a_ref[...]
        contrib = jnp.dot(s_tile, a, preferred_element_type=jnp.float32)
        o_ref[...] += contrib

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((2,), lambda mi, di, ni: (0,)),
            pl.BlockSpec((block_n, block_d), lambda mi, di, ni: (ni, di)),
        ],
        out_specs=pl.BlockSpec((block_m, block_d), lambda mi, di, ni: (mi, di)),
        out_shape=jax.ShapeDtypeStruct((m_pad, d), jnp.float32),
        interpret=interpret,
    )(key_words, A)
