"""Pallas TPU kernels for the paper's three sketching hot spots.

The paper's compute cost is dominated by *applying* the sketch (S·A): the Hadamard
transform of the ROS sketch, the sparse scatter of SJLT, and the dense Gaussian
projection. Each kernel ships as:

  kernel.py — pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper (padding, grid choice, PRNG plumbing)
  ref.py    — pure-jnp oracle used by the allclose test sweeps

All kernels are validated in interpret=True mode on CPU (this container) and written
against TPU v5e constraints (last-dim 128 lanes, MXU-shaped matmuls, VMEM budgets).
"""
from repro.kernels import fwht, sjlt, gaussian, rademacher
