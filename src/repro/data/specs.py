"""ShapeDtypeStruct stand-ins for every model input — the dry-run's "data loader".

No allocation happens here: the dry-run lowers against these specs, so a 314B-param
(arch × shape × mesh) cell costs compile time only.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec
from repro.distributed.sharding import ShardingRules


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, jax.ShapeDtypeStruct]:
    """Model inputs for one (arch × shape) cell.

    train/prefill: token batch (+ frontend stubs: VLM patch embeddings, whisper frame
    embeddings). decode: one token per sequence + the scalar position (the KV cache is
    a separate argument whose specs come from ``models.cache_shapes``).
    """
    B, S = shape.global_batch, shape.seq_len
    f = jax.ShapeDtypeStruct
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    if shape.mode == "decode":
        return {"tokens": f((B,), jnp.int32), "pos": f((), jnp.int32)}
    specs = {
        "tokens": f((B, S), jnp.int32),
        "loss_mask": f((B, S), jnp.float32),
    }
    if shape.mode == "train":
        specs["labels"] = f((B, S), jnp.int32)
    if cfg.vlm:
        specs["patches"] = f((B, cfg.num_image_tokens, cfg.vit_dim), dt)
    if cfg.encdec:
        specs["frames"] = f((B, cfg.enc_seq, cfg.d_model), dt)
    return specs


def batch_pspecs(cfg: ArchConfig, shape: ShapeSpec, rules: ShardingRules):
    """PartitionSpecs for the input batch: batch dim over dp, everything else local."""
    dp = rules.resolve("dp")
    if shape.mode == "decode":
        return {"tokens": P(dp), "pos": P()}
    specs = {"tokens": P(dp, None), "loss_mask": P(dp, None)}
    if shape.mode == "train":
        specs["labels"] = P(dp, None)
    if cfg.vlm:
        specs["patches"] = P(dp, None, None)
    if cfg.encdec:
        specs["frames"] = P(dp, None, None)
    return specs


def batch_shardings(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh, rules: ShardingRules):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), batch_pspecs(cfg, shape, rules)
    )
