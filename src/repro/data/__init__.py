"""Deterministic synthetic data: LM token streams + the paper's regression workloads.

Every batch is a pure function of (seed, step[, shard]) — a restarted or replaced
worker regenerates exactly the same data, which is what makes checkpoint-restart and
elastic rescaling bitwise-reproducible (no data-loader state to save).
"""
from repro.data.tokens import lm_batch, lm_eval_batch
from repro.data.regression import (
    gaussian_regression,
    student_t_regression,
    airline_like,
    emnist_like,
)
from repro.data.specs import input_specs, batch_shardings
