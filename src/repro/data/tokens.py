"""Synthetic LM token pipeline: a learnable affine-bigram language.

tokens[t+1] = (a·tokens[t] + c) mod V with probability p, else uniform noise — enough
structure that cross-entropy falls measurably within tens of steps on a tiny model,
while staying a closed-form function of (seed, step, row) so any shard of any batch
can be regenerated independently (fault tolerance / elastic rescale for free).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def _row_key(seed: int, step, row):
    k = jax.random.PRNGKey(seed)
    k = jax.random.fold_in(k, step)
    return jax.random.fold_in(k, row)


@functools.partial(jax.jit, static_argnames=("batch", "seq", "vocab", "row_offset", "seed", "p_pattern"))
def lm_batch(
    seed: int,
    step: jax.Array | int,
    *,
    batch: int,
    seq: int,
    vocab: int,
    row_offset: int = 0,
    p_pattern: float = 0.9,
):
    """One batch {tokens, labels, loss_mask}. Rows [row_offset, row_offset+batch)."""
    a = 31337 % vocab or 1
    c = 7919 % vocab

    def row(r):
        k = _row_key(seed, step, r + row_offset)
        k0, k1, k2 = jax.random.split(k, 3)
        start = jax.random.randint(k0, (), 0, vocab)
        noise = jax.random.randint(k1, (seq,), 0, vocab)
        use_pat = jax.random.bernoulli(k2, p_pattern, (seq,))

        def scan_fn(tok, xs):
            nz, up = xs
            nxt = jnp.where(up, (a * tok + c) % vocab, nz)
            return nxt, nxt

        _, toks = jax.lax.scan(scan_fn, start, (noise, use_pat))
        return toks

    tokens = jax.vmap(row)(jnp.arange(batch)).astype(jnp.int32)
    return {
        "tokens": tokens,
        "labels": tokens,  # lm_loss shifts internally
        "loss_mask": jnp.ones((batch, seq), jnp.float32),
    }


def lm_eval_batch(seed: int, step, *, batch: int, seq: int, vocab: int):
    """Held-out split: disjoint row space from training (rows offset by 2^20)."""
    return lm_batch(seed, step, batch=batch, seq=seq, vocab=vocab, row_offset=1 << 20)
