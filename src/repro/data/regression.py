"""The paper's regression workloads, regenerated synthetically (offline container).

Each generator returns (A, b, meta). ``b`` may be (n,) or (n, k) (multi-target — the
EMNIST one-hot least squares). Heavy-tailed student-t data reproduces the Fig. 3
conditioning regime; ``airline_like`` mimics the dummy-coded categorical structure of
the paper's main dataset (mostly-sparse 0/1 features + a few numeric columns).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp


def gaussian_regression(key, n: int, d: int, *, noise: float = 0.1, planted: bool = True):
    ka, kx, ke = jax.random.split(key, 3)
    A = jax.random.normal(ka, (n, d))
    if planted:
        x = jax.random.normal(kx, (d,))
        b = A @ x + noise * jax.random.normal(ke, (n,))
    else:
        b = jax.random.normal(ke, (n,))
        x = None
    return A, b, {"x_truth": x}


def student_t_regression(key, n: int, d: int, *, df: float = 1.5, noise: float = 0.1):
    """Paper Fig. 3: A entries ~ student-t(df) (heavy-tailed, high row-coherence)."""
    ka, kx, ke = jax.random.split(key, 3)
    A = jax.random.t(ka, df, (n, d))
    # clip the extreme tail so f(x*) is finite-variance enough for Monte Carlo runs
    A = jnp.clip(A, -1e3, 1e3)
    x = jax.random.normal(kx, (d,))
    b = A @ x + noise * jax.random.normal(ke, (n,))
    return A, b, {"x_truth": x}


def airline_like(key, n: int, *, cards=(12, 31, 7, 24, 60), numeric: int = 2, noise: float = 0.3):
    """Dummy-coded categorical design like the paper's airline matrix.

    ``cards`` are category cardinalities (month, day-of-month, day-of-week, hour, ...);
    each contributes a one-hot block. d = sum(cards) + numeric. The planted output is
    a logit-ish linear response thresholded to {0,1} (the DepDelay>15 target).
    """
    keys = jax.random.split(key, len(cards) + 3)
    blocks = []
    for i, c in enumerate(cards):
        idx = jax.random.randint(keys[i], (n,), 0, c)
        blocks.append(jax.nn.one_hot(idx, c, dtype=jnp.float32))
    num = jax.random.lognormal(keys[-3], shape=(n, numeric)) / 5.0  # distance-ish
    A = jnp.concatenate(blocks + [num], axis=1)
    d = A.shape[1]
    x = jax.random.normal(keys[-2], (d,)) / math.sqrt(d)
    score = A @ x + noise * jax.random.normal(keys[-1], (n,))
    b = (score > jnp.median(score)).astype(jnp.float32)
    return A, b, {"x_truth": x, "d": d}


def emnist_like(key, n: int, *, classes: int = 47, img_dim: int = 784, noise: float = 1.0):
    """Class-structured image-like data for the Fig. 2 experiment: rows are noisy
    class templates, b is the one-hot label matrix (least squares as multiclass).

    Class frequencies are Zipf-skewed and template norms vary ~8× — real EMNIST rows
    have very uneven leverage (that is *why* the paper's Fig. 2 shows SJLT beating
    uniform sampling); an i.i.d.-homogeneous stand-in would hide the effect."""
    kt, kl, ke, ks = jax.random.split(key, 4)
    templates = jax.random.normal(kt, (classes, img_dim)) * 2.0
    scale = jnp.exp(jnp.linspace(jnp.log(0.5), jnp.log(4.0), classes))
    templates = templates * scale[:, None]
    probs = 1.0 / (1.0 + jnp.arange(classes, dtype=jnp.float32))
    labels = jax.random.categorical(kl, jnp.log(probs / probs.sum()), shape=(n,))
    A = templates[labels] + noise * jax.random.normal(ke, (n, img_dim))
    B = jax.nn.one_hot(labels, classes, dtype=jnp.float32)
    return A, B, {"labels": labels}


def accuracy(A, B_onehot, X, labels) -> jax.Array:
    """Multiclass accuracy of the least-squares classifier X (img_dim, classes)."""
    pred = jnp.argmax(A @ X, axis=1)
    return jnp.mean((pred == labels).astype(jnp.float32))
