"""Checkpoint store: per-leaf raw binaries + a JSON manifest, atomic, async, elastic.

Design targets for 1000-node operation:
  * **atomic**   — a checkpoint is written into ``step_XXXXXXXX.tmp`` and
    ``os.replace``d into place only after every leaf and the manifest are fsynced;
    a crash mid-save can never leave a half-readable "latest" step.
  * **elastic**  — leaves are stored as *global* arrays (gathered via
    ``jax.device_get``, which handles sharded inputs); restore re-shards onto
    whatever mesh the restarted job has, so q can change across restarts (the paper's
    elasticity claim, applied to training state).
  * **async**    — ``AsyncCheckpointer`` snapshots to host memory synchronously
    (cheap) and writes to disk on a worker thread, overlapping I/O with the next
    training steps; ``wait()`` joins before the next save or at shutdown.
  * **self-describing** — the manifest stores the flattened key-paths, shapes and
    dtypes; restore validates against the expected tree and fails loudly on mismatch.

bfloat16 (no numpy dtype) is stored as raw uint16 with the logical dtype recorded in
the manifest.
"""
from __future__ import annotations

import json
import os
import re
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

try:  # ml_dtypes ships with jax
    import ml_dtypes

    _BF16 = np.dtype(ml_dtypes.bfloat16)
except Exception:  # pragma: no cover
    _BF16 = None

PyTree = Any
_STEP_RE = re.compile(r"^step_(\d{8})$")


def _path_str(path) -> str:
    parts = []
    for p in path:
        parts.append(str(getattr(p, "key", getattr(p, "idx", p))))
    return "/".join(parts)


def _leaf_filename(i: int) -> str:
    return f"leaf_{i:05d}.bin"


def save_checkpoint(directory: str, step: int, tree: PyTree) -> str:
    """Write ``tree`` as ``directory/step_XXXXXXXX``. Returns the final path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        import shutil

        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    manifest = {"step": step, "leaves": []}
    for i, (path, leaf) in enumerate(leaves_with_paths):
        arr = np.asarray(jax.device_get(leaf))
        logical_dtype = str(arr.dtype)
        if _BF16 is not None and arr.dtype == _BF16:
            arr = arr.view(np.uint16)
            logical_dtype = "bfloat16"
        fname = _leaf_filename(i)
        with open(os.path.join(tmp, fname), "wb") as f:
            f.write(np.ascontiguousarray(arr).tobytes())
            f.flush()
            os.fsync(f.fileno())
        manifest["leaves"].append(
            {"path": _path_str(path), "file": fname, "shape": list(arr.shape), "dtype": logical_dtype}
        )
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        import shutil

        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    """Highest complete step in ``directory`` (tmp dirs are ignored), or None."""
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        m = _STEP_RE.match(name)
        if m and os.path.exists(os.path.join(directory, name, "manifest.json")):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def restore_checkpoint(
    directory: str,
    step: int,
    like: PyTree,
    *,
    shardings: Optional[PyTree] = None,
) -> PyTree:
    """Load step into the structure of ``like`` (arrays or ShapeDtypeStructs).

    ``shardings``: optional pytree of NamedShardings — restore onto *any* mesh
    (elastic restart); None keeps arrays on the default device.
    """
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)

    leaves_with_paths = jax.tree_util.tree_flatten_with_path(like)[0]
    treedef = jax.tree_util.tree_structure(like)
    by_path = {e["path"]: e for e in manifest["leaves"]}
    out_leaves = []
    shard_leaves = (
        jax.tree_util.tree_leaves(shardings) if shardings is not None else [None] * len(leaves_with_paths)
    )
    for (path, leaf), shard in zip(leaves_with_paths, shard_leaves):
        ps = _path_str(path)
        if ps not in by_path:
            raise KeyError(f"checkpoint {d} is missing leaf {ps!r}")
        entry = by_path[ps]
        if list(leaf.shape) != entry["shape"]:
            raise ValueError(f"shape mismatch for {ps}: ckpt {entry['shape']} vs expected {list(leaf.shape)}")
        raw = open(os.path.join(d, entry["file"]), "rb").read()
        if entry["dtype"] == "bfloat16":
            arr = np.frombuffer(raw, np.uint16).reshape(entry["shape"]).view(_BF16)
        else:
            arr = np.frombuffer(raw, np.dtype(entry["dtype"])).reshape(entry["shape"])
        if shard is not None:
            out_leaves.append(jax.device_put(arr, shard))
        else:
            out_leaves.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out_leaves)


class AsyncCheckpointer:
    """Overlap checkpoint I/O with training: snapshot now, write on a thread."""

    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree: PyTree) -> None:
        self.wait()
        # Snapshot to host memory synchronously — the training loop may mutate/donate
        # the device buffers right after this returns.
        host = jax.tree_util.tree_map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save_checkpoint(self.directory, step, host)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = sorted(
            int(m.group(1))
            for m in (_STEP_RE.match(n) for n in os.listdir(self.directory))
            if m
        )
        import shutil

        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True)
