"""Sharding rules: logical axes → mesh axes → PartitionSpecs for params & activations.

The framework uses three logical axes:

  * ``dp``     — data parallel (batch dim of activations). Maps to ("pod", "data") on
                 the multi-pod mesh so the batch spreads over both; pure-DP across pods
                 keeps the only cross-pod collective the gradient reduce (DCN-friendly).
  * ``fsdp``   — fully-sharded parameter dim (ZeRO-3 style). Maps to "data": each layer
                 is all-gathered just-in-time inside the layer scan, so per-device
                 parameter memory is params/|data| + one layer.
  * ``tensor`` — Megatron tensor parallelism (attention heads / FFN hidden / vocab /
                 MoE expert-ffn hidden). Maps to "model".

Param specs are assigned by *leaf path* pattern matching, which keeps the model code
free of sharding annotations (the model only constrains activations via
:func:`constrain`). Rules were chosen so every matmul has at most one sharded
contraction operand → one reduce per projection, matching the Megatron schedule.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Logical-axis → mesh-axis mapping. ``None`` disables an axis (replicate)."""

    dp: Tuple[str, ...] = ("data",)       # activation batch
    fsdp: Any = "data"                    # parameter shard axis/axes (ZeRO-3); may
    #                                       be a tuple ("pod","data") to span pods
    tensor: Optional[str] = "model"       # Megatron TP axis
    sequence_parallel: bool = True        # layer-boundary acts sharded (dp, tensor)

    def resolve(self, logical: Optional[str]):
        if logical is None:
            return None
        if logical == "dp":
            return self.dp if len(self.dp) > 1 else (self.dp[0] if self.dp else None)
        if logical == "fsdp":
            return self.fsdp
        if logical == "tensor":
            return self.tensor
        if logical == "sp":
            # sequence axis of activations: rides the tensor axis (Megatron-SP) so
            # per-layer remat residuals and attention score tiles divide by |tensor|
            return self.tensor if self.sequence_parallel else None
        raise ValueError(f"unknown logical axis {logical!r}")

    def spec(self, *logical_axes) -> P:
        return P(*[self.resolve(a) for a in logical_axes])


DEFAULT_RULES = ShardingRules()
REPLICATED_RULES = ShardingRules(dp=(), fsdp=None, tensor=None)


def constrain(x: jax.Array, rules: Optional[ShardingRules], *logical_axes) -> jax.Array:
    """with_sharding_constraint if rules are active (inside jit under a mesh);
    identity when rules is None (single-device tests / examples).

    (§Perf iter 5, refuted: wrapping this in ``optimization_barrier`` to pin the
    resharding collectives to bf16 tensors changed NOTHING in the compiled
    collective schedule — GSPMD places reshards during partitioning, before the
    convert-motion passes a barrier could block. Reverted to keep fusion free.)"""
    if rules is None:
        return x
    ndim_axes = list(logical_axes) + [None] * (x.ndim - len(logical_axes))
    return jax.lax.with_sharding_constraint(x, rules.spec(*ndim_axes))


# ------------------------------------------------------------------- param rules
#
# (regex on "/"-joined tree path, logical axes for the *trailing* dims of the leaf).
# Leading unmatched dims (the stacked-layer axis L, MoE expert axis E) are replicated
# unless the rule names them explicitly. First match wins.

_PARAM_RULES = [
    # embeddings: vocab-parallel (Megatron), fsdp on d
    (r"embed/table$", ("tensor", "fsdp")),
    (r"unembed/w$", ("fsdp", "tensor")),
    (r"vit_proj/w$", (None, None)),
    # attention (leaf shapes (L, d, H*hd) / (L, H*hd, d))
    (r"attn/wq$", (None, "fsdp", "tensor")),
    (r"attn/wk$", (None, "fsdp", "tensor")),
    (r"attn/wv$", (None, "fsdp", "tensor")),
    (r"attn/wo$", (None, "tensor", "fsdp")),
    (r"xattn/wq$", (None, "fsdp", "tensor")),
    (r"xattn/wk$", (None, "fsdp", "tensor")),
    (r"xattn/wv$", (None, "fsdp", "tensor")),
    (r"xattn/wo$", (None, "tensor", "fsdp")),
    # MLA: low-rank downs replicated-ish (small), ups tensor-parallel on heads
    (r"attn/w_dq$", (None, "fsdp", None)),
    (r"attn/w_uq$", (None, None, "tensor")),
    (r"attn/w_dkv$", (None, "fsdp", None)),
    (r"attn/w_ukv$", (None, None, "tensor")),
    # dense FFN (L, d, f) / (L, f, d)
    (r"ffn/w_gate$", (None, "fsdp", "tensor")),
    (r"ffn/w_up$", (None, "fsdp", "tensor")),
    (r"ffn/w_down$", (None, "tensor", "fsdp")),
    # MoE (L, E, d, f) / (L, E, f, d): TP over the expert-ffn hidden dim; experts
    # stay whole (the sort-based dispatch never crosses the data shard).
    (r"moe/router$", (None, "fsdp", None)),
    (r"moe/w_gate$", (None, None, "fsdp", "tensor")),
    (r"moe/w_up$", (None, None, "fsdp", "tensor")),
    (r"moe/w_down$", (None, None, "tensor", "fsdp")),
    # mamba (channel dim C = d_inner is the TP axis)
    (r"mamba/in_proj$", (None, "fsdp", "tensor")),
    (r"mamba/conv_w$", (None, None, "tensor")),
    (r"mamba/conv_b$", (None, "tensor")),
    (r"mamba/x_proj$", (None, "tensor", None)),
    (r"mamba/dt_proj_w$", (None, None, "tensor")),
    (r"mamba/dt_proj_b$", (None, "tensor")),
    (r"mamba/A_log$", (None, "tensor", None)),
    (r"mamba/D$", (None, "tensor")),
    (r"mamba/out_proj$", (None, "tensor", "fsdp")),
    # norms & everything small: replicated
    (r".*", None),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def spec_for_path(path_s: str, ndim: int, rules: ShardingRules) -> P:
    """PartitionSpec for one leaf. Rules give trailing-dim axes; leading dims None."""
    for pat, logical in _PARAM_RULES:
        if re.search(pat, path_s):
            if logical is None:
                return P()
            axes = list(logical)
            # encoder stacks reuse attn/ffn rules but may have the same ndim; pad or
            # trim *leading* positions so trailing dims line up.
            if len(axes) < ndim:
                axes = [None] * (ndim - len(axes)) + axes
            elif len(axes) > ndim:
                axes = axes[len(axes) - ndim:]
            resolved = [rules.resolve(a) for a in axes]
            return P(*resolved)
    return P()


def param_pspecs(params_tree, rules: ShardingRules):
    """Pytree of PartitionSpecs matching ``params_tree`` (arrays or ShapeDtypeStructs)."""
    def leaf_spec(path, leaf):
        return spec_for_path(_path_str(path), len(leaf.shape), rules)

    return jax.tree_util.tree_map_with_path(leaf_spec, params_tree)


def named_shardings(params_tree, mesh: Mesh, rules: ShardingRules):
    """Pytree of NamedShardings for device_put / jit in_shardings."""
    specs = param_pspecs(params_tree, rules)
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), specs)


# ------------------------------------------------------------------- cache rules


def cache_pspecs(cache_tree, rules: ShardingRules, *, batch_sharded: bool):
    """KV/state cache PartitionSpecs.

    batch_sharded=True (decode_32k): batch dim → dp, *sequence* dim → tensor. The
    decode softmax then reduces across model shards (flash-decode / split-KV style).
    Sharding the KV-head dim instead would be illegal for most assigned archs
    (kv_heads ∈ {2, 8} < |model| = 16) and sharding head_dim would split RoPE pairs.

    batch_sharded=False (long_500k, batch=1): the sequence dim is sharded over
    *every* mesh axis (dp + tensor — 256 or 512 ways); SSM states shard their channel
    dim over tensor only (they have no sequence axis — that is the point of SSMs).

    Cache leaf layouts (leading L = stacked layers):
      k/v       (L, B, S, KV, hd)
      ckv       (L, B, S, r)         (MLA latent)
      krope     (L, B, S, rope_d)
      conv      (L, B, K-1, C)       (mamba; C → tensor)
      ssm       (L, B, C, N)
      xk/xv     (L, B, S_enc, KV, hd)
    """
    dp = rules.resolve("dp")
    tp = rules.resolve("tensor")

    def _axes(*logical):
        out = []
        for a in logical:
            if a is None:
                continue
            if isinstance(a, tuple):
                out.extend(x for x in a if x)
            else:
                out.append(a)
        return tuple(out) if out else None

    seq_all = _axes(dp, tp)  # long-context: sequence over the whole mesh

    def leaf_spec(path, leaf):
        name = _path_str(path).split("/")[-1]
        nd = len(leaf.shape)
        if name in ("xk", "xv"):
            # whisper cross-attn cache: S_enc = 1500 is not shard-divisible and the
            # tensor is small — shard batch only.
            return P(None, dp if batch_sharded else None, None, None, None)
        if name in ("k", "v"):
            if batch_sharded:
                return P(None, dp, tp, None, None)
            return P(None, None, seq_all, None, None)
        if name in ("ckv", "krope"):
            if batch_sharded:
                return P(None, dp, tp, None)
            return P(None, None, seq_all, None)
        if name == "conv":
            return P(None, dp if batch_sharded else None, None, tp)
        if name == "ssm":
            return P(None, dp if batch_sharded else None, tp, None)
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(leaf_spec, cache_tree)
