"""Fault tolerance & elasticity: the systems contract behind the paper's claims.

The paper's computing model (i.i.d. serverless workers + a master that averages
whatever arrived) is the *easy* case of fault tolerance; this module carries the same
guarantees over to the stateful parts of the framework:

  * ``StragglerPolicy``    — deadline-based masks for any psum-averaged quantity
    (sketched solutions, DP gradients). Pure simulation on CPU; on a real deployment
    the mask would come from a per-step heartbeat. Policies adapt onto the runtime
    engine's latency layer via :meth:`StragglerPolicy.to_latency_model` — the async
    engine (``repro.runtime``) consumes ``LatencyModel``s, so one straggler
    description drives both the synchronous mask simulation and the event-driven
    execution.
  * ``elastic_restore``    — restore any checkpoint onto any mesh: leaves are stored
    as global arrays, so q (and the mesh shape) may change between runs. Combined
    with deterministic data (pure function of step) a rescaled job continues the
    *same* optimization trajectory modulo DP-width-induced batch layout.
  * ``HeartbeatMonitor``   — bookkeeping for worker liveness used by the trainer
    demos: records per-step arrival times, derives masks, and reports straggler
    statistics (the quantity Fig. 1's run-time captions measure).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.checkpoint import restore_checkpoint
from repro.core.averaging import simulate_straggler_mask

PyTree = Any


@dataclasses.dataclass
class StragglerPolicy:
    """How the master decides which workers count for this step's average."""

    drop_prob: float = 0.0           # hard failures (worker never reports)
    deadline_quantile: float = 1.0   # keep only the fastest fraction
    seed: int = 0

    def mask_for_step(self, step: int, q: int) -> jax.Array:
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        return simulate_straggler_mask(
            key, q, drop_prob=self.drop_prob, deadline_quantile=self.deadline_quantile
        )

    def to_latency_model(self, *, mean_s: float = 1.0, sigma: float = 0.35):
        """The equivalent :class:`repro.runtime.latency.LatencyModel`: lognormal
        runtimes (median ``mean_s``) with ``drop_prob`` hard failures layered on.
        Feed :meth:`deadline_for` to the engine to reproduce ``deadline_quantile``
        as a wall-clock cutoff instead of an order statistic."""
        from repro.runtime.latency import DropLatency, LognormalLatency

        inner = LognormalLatency(seed=self.seed, mean_s=mean_s, sigma=sigma)
        return DropLatency(seed=self.seed, inner=inner, drop_prob=self.drop_prob)

    def deadline_for(self, *, mean_s: float = 1.0, sigma: float = 0.35) -> float:
        """The latency cutoff at which a lognormal wave keeps ~``deadline_quantile``
        of its workers (math.inf when the policy keeps everyone)."""
        import math

        if self.deadline_quantile >= 1.0:
            return math.inf
        from repro.runtime.latency import LognormalLatency

        return LognormalLatency(mean_s=mean_s, sigma=sigma).quantile(self.deadline_quantile)

    def to_deadline_policy(
        self, *, mean_s: float = 1.0, sigma: float = 0.35, adaptive: bool = False
    ):
        """The engine-side :class:`~repro.runtime.engine.DeadlinePolicy` equivalent
        of ``deadline_quantile``: a static cutoff at the lognormal quantile, or —
        with ``adaptive=True`` — an :class:`~repro.runtime.engine.AdaptiveDeadline`
        warm-started there that keeps targeting the same quantile from the
        *observed* telemetry stream instead of the assumed lognormal."""
        import math

        from repro.runtime.engine import AdaptiveDeadline, StaticDeadline

        cutoff = self.deadline_for(mean_s=mean_s, sigma=sigma)
        if not adaptive:
            return StaticDeadline(deadline_s=cutoff)
        warmup = cutoff if math.isfinite(cutoff) else 4.0 * mean_s
        quantile = self.deadline_quantile if self.deadline_quantile < 1.0 else 0.95
        return AdaptiveDeadline(warmup_s=warmup, quantile=quantile)


class HeartbeatMonitor:
    """Tracks simulated worker arrival times; produces masks + reports.

    The runtime engine's telemetry subsumes this report
    (``EventLog.heartbeat_report`` replays an engine run into a monitor), so the
    schema here — including the p50 / timeout / retry extensions — is the one
    summary format shared by synchronous trainer steps and async engine runs.
    """

    def __init__(self, q: int, *, deadline: float):
        self.q = q
        self.deadline = deadline
        self.arrivals: List[np.ndarray] = []
        self.timeouts = 0
        self.retries = 0

    def record_step(self, runtimes: np.ndarray) -> np.ndarray:
        """runtimes: (q,) seconds. Returns the 0/1 mask of on-time workers."""
        self.arrivals.append(runtimes)
        return (runtimes <= self.deadline).astype(np.float32)

    def record_timeout(self, count: int = 1) -> None:
        """A worker blew its deadline (engine ``timeout`` events)."""
        self.timeouts += int(count)

    def record_retry(self, count: int = 1) -> None:
        """A timed-out task was resubmitted with a fresh sketch (``retry`` events)."""
        self.retries += int(count)

    def report(self) -> Dict[str, float]:
        if not self.arrivals:
            return {}
        r = np.stack(self.arrivals)
        finite = r[np.isfinite(r)]
        on_time = (r <= self.deadline).mean()
        return {
            "steps": float(r.shape[0]),
            "mean_runtime": float(finite.mean()) if finite.size else float("inf"),
            "p50_runtime": float(np.quantile(finite, 0.50)) if finite.size else float("inf"),
            "p95_runtime": float(np.quantile(finite, 0.95)) if finite.size else float("inf"),
            "on_time_fraction": float(on_time),
            "effective_q": float(on_time * self.q),
            "timeouts": float(self.timeouts),
            "retries": float(self.retries),
        }


def elastic_restore(
    directory: str,
    step: int,
    like: PyTree,
    mesh: Mesh,
    pspecs: PyTree,
) -> PyTree:
    """Restore a checkpoint onto ``mesh`` (any shape/size — elastic rescale)."""
    shardings = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        pspecs,
        is_leaf=lambda x: hasattr(x, "_normalized_spec") or type(x).__name__ == "PartitionSpec",
    )
    return restore_checkpoint(directory, step, like, shardings=shardings)
