"""Distribution layer: sharding rules, collectives helpers, fault tolerance."""
from repro.distributed.sharding import (
    ShardingRules,
    DEFAULT_RULES,
    constrain,
    param_pspecs,
    named_shardings,
)
