"""Batched serving engine: flash prefill → step-synchronized batched decode.

The engine keeps one fixed-shape decode batch (padding short prompts) so the jitted
``decode_step`` is compiled once; requests are packed into the batch, generated to
their individual max-token limits, and unpacked. Greedy and temperature sampling.

Production notes encoded here (and exercised by tests):
  * prefill and decode are separate compilations — prefill cost is amortized once
    per request, decode is the steady-state loop;
  * the KV cache is allocated once at ``max_len`` and threaded functionally;
  * EOS handling is mask-based: finished rows keep decoding into a dead slot
    (fixed shapes beat ragged early-exit on TPU), outputs are trimmed on the host.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import lm

PyTree = object


def sample_token(key: jax.Array, logits: jax.Array, temperature: float = 0.0) -> jax.Array:
    """(B, V) logits -> (B,) token ids. temperature<=0 is greedy."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature, axis=-1).astype(jnp.int32)


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 8
    max_len: int = 256          # prompt + generation budget (cache allocation)
    temperature: float = 0.0
    eos_id: int = -1            # -1: never stop early
    seed: int = 0


class Engine:
    def __init__(self, cfg: ArchConfig, params: PyTree, sc: ServeConfig):
        self.cfg = cfg
        self.params = params
        self.sc = sc

        def _mask_pad(logits):
            # padded-vocab ids (Megatron-style table padding) must never be sampled
            if cfg.padded_vocab > cfg.vocab_size:
                neg = jnp.full((cfg.padded_vocab - cfg.vocab_size,), -1e30, logits.dtype)
                logits = logits.at[..., cfg.vocab_size :].set(neg)
            return logits

        self._mask_pad = _mask_pad

        def _prefill(params, batch):
            logits, cache = lm.batched_prefill(params, cfg, batch, cache_len=sc.max_len)
            return _mask_pad(logits), cache

        def _decode(params, tok, cache, pos, key):
            logits, cache = lm.decode_step(params, cfg, tok, cache, pos)
            logits = _mask_pad(logits)
            nxt = sample_token(key, logits, sc.temperature)
            return nxt, logits, cache

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode)

    # ------------------------------------------------------------------ API
    def generate(
        self,
        prompts: Sequence[Sequence[int]],
        *,
        max_new_tokens: int = 32,
        frames: Optional[jax.Array] = None,
        patches: Optional[jax.Array] = None,
    ) -> List[List[int]]:
        """Generate continuations for up to max_batch prompts (step-synchronized)."""
        out: List[List[int]] = []
        for i in range(0, len(prompts), self.sc.max_batch):
            chunk = prompts[i : i + self.sc.max_batch]
            out.extend(self._generate_batch(chunk, max_new_tokens, frames, patches))
        return out

    def _generate_batch(self, prompts, max_new_tokens, frames, patches) -> List[List[int]]:
        B = len(prompts)
        S = max(len(p) for p in prompts)
        assert S + max_new_tokens <= self.sc.max_len, "raise ServeConfig.max_len"
        # left-pad to a rectangle; padded prefix tokens are position-consistent but
        # their K/V are masked out of nothing — they are ordinary tokens the model
        # simply ignores at sampling time (standard fixed-shape serving trade-off).
        toks = np.zeros((B, S), np.int32)
        for r, p in enumerate(prompts):
            toks[r, S - len(p) :] = np.asarray(p, np.int32)
        batch = {"tokens": jnp.asarray(toks)}
        if patches is not None:
            batch["patches"] = patches[:B]
        if frames is not None:
            batch["frames"] = frames[:B]

        logits, cache = self._prefill(self.params, batch)
        key = jax.random.PRNGKey(self.sc.seed)
        tok = sample_token(key, logits, self.sc.temperature)
        generated = [tok]
        for t in range(1, max_new_tokens):
            key, sub = jax.random.split(key)
            tok, _, cache = self._decode(self.params, tok, cache, jnp.int32(S + t - 1), sub)
            generated.append(tok)
        gen = np.stack([np.asarray(g) for g in generated], axis=1)  # (B, T)
        outs: List[List[int]] = []
        for r in range(B):
            row = gen[r].tolist()
            if self.sc.eos_id >= 0 and self.sc.eos_id in row:
                row = row[: row.index(self.sc.eos_id) + 1]
            outs.append(row)
        return outs
