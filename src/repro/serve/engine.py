"""Serving engines: LM decode batching + sketch-solve job admission.

Two serving surfaces share this module:

  * :class:`Engine` — the batched LM engine (flash prefill → step-synchronized
    batched decode over a fixed-shape KV cache).
  * :class:`SolveServer` — the *sketch-least-squares* front end: a job-admission
    API (:meth:`SolveServer.submit_solve`) that routes regression jobs through
    the async :class:`~repro.runtime.engine.ServerlessEngine` — streaming Welford
    averages, deadline→backoff→retry (adaptive deadlines optional), early stop,
    and a per-job telemetry summary — on any executor backend
    (``inline``/``thread``/``process``).

LM engine production notes encoded here (and exercised by tests):
  * prefill and decode are separate compilations — prefill cost is amortized once
    per request, decode is the steady-state loop;
  * the KV cache is allocated once at ``max_len`` and threaded functionally;
  * EOS handling is mask-based: finished rows keep decoding into a dead slot
    (fixed shapes beat ragged early-exit on TPU), outputs are trimmed on the host.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import lm

PyTree = object


def sample_token(key: jax.Array, logits: jax.Array, temperature: float = 0.0) -> jax.Array:
    """(B, V) logits -> (B,) token ids. temperature<=0 is greedy."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature, axis=-1).astype(jnp.int32)


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 8
    max_len: int = 256          # prompt + generation budget (cache allocation)
    temperature: float = 0.0
    eos_id: int = -1            # -1: never stop early
    seed: int = 0


class Engine:
    def __init__(self, cfg: ArchConfig, params: PyTree, sc: ServeConfig):
        self.cfg = cfg
        self.params = params
        self.sc = sc

        def _mask_pad(logits):
            # padded-vocab ids (Megatron-style table padding) must never be sampled
            if cfg.padded_vocab > cfg.vocab_size:
                neg = jnp.full((cfg.padded_vocab - cfg.vocab_size,), -1e30, logits.dtype)
                logits = logits.at[..., cfg.vocab_size :].set(neg)
            return logits

        self._mask_pad = _mask_pad

        def _prefill(params, batch):
            logits, cache = lm.batched_prefill(params, cfg, batch, cache_len=sc.max_len)
            return _mask_pad(logits), cache

        def _decode(params, tok, cache, pos, key):
            logits, cache = lm.decode_step(params, cfg, tok, cache, pos)
            logits = _mask_pad(logits)
            nxt = sample_token(key, logits, sc.temperature)
            return nxt, logits, cache

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode)

    # ------------------------------------------------------------------ API
    def generate(
        self,
        prompts: Sequence[Sequence[int]],
        *,
        max_new_tokens: int = 32,
        frames: Optional[jax.Array] = None,
        patches: Optional[jax.Array] = None,
    ) -> List[List[int]]:
        """Generate continuations for up to max_batch prompts (step-synchronized)."""
        out: List[List[int]] = []
        for i in range(0, len(prompts), self.sc.max_batch):
            chunk = prompts[i : i + self.sc.max_batch]
            out.extend(self._generate_batch(chunk, max_new_tokens, frames, patches))
        return out

    def _generate_batch(self, prompts, max_new_tokens, frames, patches) -> List[List[int]]:
        B = len(prompts)
        S = max(len(p) for p in prompts)
        assert S + max_new_tokens <= self.sc.max_len, "raise ServeConfig.max_len"
        # left-pad to a rectangle; padded prefix tokens are position-consistent but
        # their K/V are masked out of nothing — they are ordinary tokens the model
        # simply ignores at sampling time (standard fixed-shape serving trade-off).
        toks = np.zeros((B, S), np.int32)
        for r, p in enumerate(prompts):
            toks[r, S - len(p) :] = np.asarray(p, np.int32)
        batch = {"tokens": jnp.asarray(toks)}
        if patches is not None:
            batch["patches"] = patches[:B]
        if frames is not None:
            batch["frames"] = frames[:B]

        logits, cache = self._prefill(self.params, batch)
        key = jax.random.PRNGKey(self.sc.seed)
        tok = sample_token(key, logits, self.sc.temperature)
        generated = [tok]
        for t in range(1, max_new_tokens):
            key, sub = jax.random.split(key)
            tok, _, cache = self._decode(self.params, tok, cache, jnp.int32(S + t - 1), sub)
            generated.append(tok)
        gen = np.stack([np.asarray(g) for g in generated], axis=1)  # (B, T)
        outs: List[List[int]] = []
        for r in range(B):
            row = gen[r].tolist()
            if self.sc.eos_id >= 0 and self.sc.eos_id in row:
                row = row[: row.index(self.sc.eos_id) + 1]
            outs.append(row)
        return outs


# ===================================================================== solve serving


@dataclasses.dataclass
class SolveJob:
    """One admitted sketch-solve job: the result plus its full provenance."""

    job_id: int
    spec: object                 # sk.SketchSpec (kept untyped to avoid import cycle)
    q: int
    backend: str
    result: object               # repro.runtime.engine.RuntimeResult
    summary: Dict

    @property
    def xbar(self) -> np.ndarray:
        return self.result.xbar

    @property
    def realized_mask(self) -> np.ndarray:
        return self.result.realized_mask


class SolveServer:
    """Job admission for distributed sketch-least-squares (the paper's Algorithm 1
    as a *service*): every submitted job runs through the async
    :class:`~repro.runtime.engine.ServerlessEngine` — the same deadline → backoff
    → retry loop, streaming Welford averaging, and early stopping the benchmarks
    exercise — and leaves a per-job telemetry summary behind.

        from repro import runtime as rt
        from repro.serve import SolveServer

        server = SolveServer(
            latency=rt.HeavyTailLatency(scale_s=0.5, alpha=1.5, seed=0),
            config=rt.RuntimeConfig(deadline_s=1.0, max_retries=2),
            backend="process",                 # or "inline" / "thread"
            deadline=rt.AdaptiveDeadline(),    # optional: rolling-p95 deadlines
        )
        job = server.submit_solve(A, b, spec, q=32, error_fn="probe")
        job.xbar, job.summary                  # solution + telemetry
        server.telemetry()                     # aggregate across jobs

    The server is synchronous at the job level (submit_solve returns the finished
    job) while each job is internally asynchronous at the task level; per-job
    determinism is inherited from the engine (same seed ⇒ byte-identical event
    log on every backend).
    """

    def __init__(
        self,
        *,
        latency,
        config=None,
        backend: Union[str, object] = "thread",
        deadline=None,
    ):
        from repro.runtime.engine import RuntimeConfig

        self.latency = latency
        self.config = config or RuntimeConfig()
        self.backend = backend
        self.deadline = deadline
        self.jobs: List[SolveJob] = []

    # ------------------------------------------------------------------ admission

    def submit_solve(
        self,
        A: jax.Array,
        b: jax.Array,
        spec,
        q: int,
        *,
        key: Optional[jax.Array] = None,
        seed: int = 0,
        rounds: int = 1,
        reg: float = 0.0,
        method: str = "fused",
        error_fn: Union[None, str, Callable[[np.ndarray, int], float]] = None,
        probe_rows: int = 1024,
        least_norm: bool = False,
        save_events: Optional[str] = None,
    ) -> SolveJob:
        """Admit one job: ``rounds`` waves of ``q`` sketch-solve workers over
        (A, b) with sketch ``spec``, averaged as results arrive.

        ``error_fn``: ``"theory"`` / ``"probe"`` / callable / None (see
        :mod:`repro.runtime.tasks`); combined with ``config.target_error`` it
        enables early stop. ``least_norm=True`` routes the §V right-sketch worker
        (n < d). ``save_events`` dumps the job's JSONL event log to that path.
        """
        from repro.runtime import tasks as rt_tasks
        from repro.runtime.engine import ServerlessEngine

        if key is None:
            key = jax.random.PRNGKey(seed)
        if least_norm:
            compute = rt_tasks.make_least_norm_compute(spec, key, A, b)
        else:
            compute = rt_tasks.make_sketch_solve_compute(
                spec, key, A, b, reg=reg, method=method
            )
        err = rt_tasks.resolve_error_fn(error_fn, spec, key, A, b, probe_rows=probe_rows)

        engine = ServerlessEngine(
            compute, self.latency, self.config,
            backend=self.backend, deadline=self.deadline,
        )
        task_list = [(w, r) for r in range(rounds) for w in range(q)]
        result = engine.run(tasks=task_list, error_fn=err)
        if save_events is not None:
            result.events.to_jsonl(save_events)

        backend_name = self.backend if isinstance(self.backend, str) else self.backend.name
        job = SolveJob(
            job_id=len(self.jobs),
            spec=spec,
            q=int(q),
            backend=backend_name,
            result=result,
            summary=result.summary(deadline=self.config.deadline_s),
        )
        self.jobs.append(job)
        return job

    # ------------------------------------------------------------------ telemetry

    def telemetry(self) -> Dict:
        """Aggregate report over every admitted job (the serving dashboard dict)."""
        n = len(self.jobs)
        agg: Dict = {
            "jobs": n,
            "backend": self.backend if isinstance(self.backend, str) else self.backend.name,
        }
        if n == 0:
            return agg
        for k in ("retries", "timeouts", "drops", "cancelled", "dispatched"):
            agg[k] = int(sum(j.summary.get(k, 0) for j in self.jobs))
        agg["effective_q_mean"] = float(np.mean([j.summary["effective_q"] for j in self.jobs]))
        agg["sim_makespan_s_mean"] = float(np.mean([j.summary["sim_makespan_s"] for j in self.jobs]))
        agg["stopped_early"] = int(sum(bool(j.summary.get("stopped_early")) for j in self.jobs))
        agg["per_job"] = [
            {
                "job_id": j.job_id,
                "q": j.q,
                "effective_q": j.summary["effective_q"],
                "retries": j.summary["retries"],
                "timeouts": j.summary["timeouts"],
                "drops": j.summary["drops"],
                "sim_makespan_s": j.summary["sim_makespan_s"],
                "final_error": j.summary.get("final_error"),
            }
            for j in self.jobs
        ]
        return agg
