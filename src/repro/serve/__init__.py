"""Serving substrate: batched KV-cache engine over the decode step."""
from repro.serve.engine import ServeConfig, Engine, sample_token
