"""Serving substrate: batched KV-cache LM engine + sketch-solve job admission."""
from repro.serve.engine import Engine, ServeConfig, SolveJob, SolveServer, sample_token

__all__ = ["Engine", "ServeConfig", "SolveJob", "SolveServer", "sample_token"]
