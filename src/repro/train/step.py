"""Jitted train step: loss → grads → AdamW, with microbatch gradient accumulation.

This is the GSPMD path: gradients are averaged across data-parallel shards by the
compiler (the batch is dp-sharded, the loss is a mean → XLA inserts the reduce).
The sketch-compressed / straggler-masked DP variant lives in ``sketch_dp.py``.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import ShardingRules, param_pspecs
from repro.models import lm
from repro.optim import AdamWConfig, adamw_update

PyTree = Any


def _constrain_like_params(grads: PyTree, rules: Optional[ShardingRules]) -> PyTree:
    """Pin gradients to their parameters' sharding.

    Perf: with FSDP/TP-sharded params, this turns the data-parallel gradient
    exchange into a *reduce-scatter* to the owning shard (wire bytes halve vs a full
    all-reduce and the result is 1/|data| per device) — iteration 2 of §Perf.
    """
    if rules is None:
        return grads
    specs = param_pspecs(grads, rules)
    return jax.tree_util.tree_map(
        lambda g, s: jax.lax.with_sharding_constraint(g, s), grads, specs
    )


def make_loss_fn(cfg: ArchConfig, *, rules=None, plan: Optional[lm.ExecPlan] = None):
    plan = plan or lm.ExecPlan()

    def loss_fn(params, batch):
        return lm.lm_loss(params, cfg, batch, rules=rules, plan=plan)

    return loss_fn


def make_train_step(
    cfg: ArchConfig,
    opt_cfg: AdamWConfig,
    *,
    rules: Optional[ShardingRules] = None,
    schedule: Optional[Callable] = None,
    plan: Optional[lm.ExecPlan] = None,
    remat: str = "full",
    accum_steps: int = 1,
    accum_dtype: str = "float32",
) -> Callable:
    """Returns ``train_step(state, batch) -> (state, metrics)`` (pure, jit-ready).

    accum_steps > 1 splits the batch's leading dim into microbatches and accumulates
    gradients in a lax.scan — peak activation memory divides by accum_steps while
    arithmetic is unchanged. ``accum_dtype``: the accumulator buffer is param-count
    sized (grok-314b: 4.9 GiB/chip in f32 even at 256-way sharding); bf16 halves it
    at a precision cost bounded by 1/accum_steps ulp per microbatch.
    """
    plan = plan or lm.ExecPlan(remat=remat)
    acc_dt = jnp.bfloat16 if accum_dtype == "bfloat16" else jnp.float32
    loss_fn = make_loss_fn(cfg, rules=rules, plan=plan)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if accum_steps <= 1:
            (loss, aux), grads = grad_fn(params, batch)
            return loss, aux, _constrain_like_params(grads, rules)

        def split(x):
            B = x.shape[0]
            mb = B // accum_steps
            return x.reshape((accum_steps, mb) + x.shape[1:])

        micro = jax.tree_util.tree_map(split, batch)

        def body(carry, mb):
            loss_acc, aux_acc, gacc = carry
            (loss, aux), g = grad_fn(params, mb)
            g = _constrain_like_params(g, rules)
            gacc = jax.tree_util.tree_map(lambda a, b: (a.astype(jnp.float32) + b.astype(jnp.float32)).astype(acc_dt), gacc, g)
            return (loss_acc + loss, {"ce": aux_acc["ce"] + aux["ce"], "moe_aux": aux_acc["moe_aux"] + aux["moe_aux"]}, gacc), None

        g0 = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, acc_dt), params)
        z = jnp.zeros((), jnp.float32)
        (loss, aux, gacc), _ = jax.lax.scan(body, (z, {"ce": z, "moe_aux": z}, g0), micro)
        inv = 1.0 / accum_steps
        grads = jax.tree_util.tree_map(lambda g: g * inv, gacc)
        return loss * inv, jax.tree_util.tree_map(lambda a: a * inv, aux), grads

    def train_step(state, batch):
        loss, aux, grads = compute_grads(state["params"], batch)
        lr_scale = schedule(state["step"]) if schedule is not None else 1.0
        new_params, new_opt, om = adamw_update(
            opt_cfg, state["params"], grads, state["opt"], lr_scale=lr_scale
        )
        new_state = {"params": new_params, "opt": new_opt, "step": state["step"] + 1}
        metrics = {"loss": loss, **aux, **om}
        return new_state, metrics

    return train_step
