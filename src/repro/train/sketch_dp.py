"""Sketch-DP: the paper's operators applied to data-parallel training comms.

Three paper mechanisms become one shard_map'd gradient exchange:
  1. **Sketched compression** (Eq. privacy/bandwidth operator): each DP worker
     projects its gradient with a shared S (E[SᵀS]=I → unbiased), the psum runs in
     sketch space (m ≪ D floats over the wire), the result is back-projected.
  2. **Straggler masking** (Algorithm 1's partial averaging): workers that missed the
     step deadline contribute 0 and the denominator is the realized worker count —
     the paper's central claim that i.i.d. contributions can be averaged over
     whatever subset arrived, applied to gradients instead of solutions.
  3. **Deterministic worker keys**: the sketch S is derived from (base key, step) so
     every worker builds the same S with zero coordination (``prng.worker_key``).

This path targets pure DP (params replicated across the dp axis); the 40-cell
production configs use the GSPMD step (train/step.py) where TP/FSDP sharding makes
whole-gradient sketching inapplicable (documented in DESIGN.md §Beyond-paper).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.utils.compat import shard_map

from repro.configs.base import ArchConfig
from repro.core import averaging, gradcomp
from repro.models import lm
from repro.optim import AdamWConfig, adamw_update
from repro.utils import tree as tu

PyTree = Any


def masked_compressed_mean(
    cfg: gradcomp.GradCompressionConfig,
    key: jax.Array,
    grads: PyTree,
    mask_local: jax.Array,
    axis_names,
) -> PyTree:
    """Straggler-resilient mean of gradients across ``axis_names`` (inside shard_map).

    Compression and masking compose because the sketch is linear:
        unsketch( psum(mask·S g) / psum(mask) ) = unsketch( S · masked-mean g ).
    """
    den = jnp.maximum(jax.lax.psum(mask_local, axis_names), 1.0)
    if not cfg.enabled:
        return jax.tree_util.tree_map(
            lambda g: jax.lax.psum(g * mask_local, axis_names) / den, grads
        )
    payload, ctx = gradcomp.compress(cfg, key, grads)
    payload = jax.lax.psum(payload * mask_local, axis_names) / den
    return gradcomp.decompress(cfg, payload, ctx)


def make_sketch_dp_step(
    cfg: ArchConfig,
    opt_cfg: AdamWConfig,
    mesh: Mesh,
    *,
    comp: Optional[gradcomp.GradCompressionConfig] = None,
    axis_names: Tuple[str, ...] = ("data",),
    schedule: Optional[Callable] = None,
    remat: str = "none",
) -> Callable:
    """Returns ``step(state, batch, key, mask) -> (state, metrics)``.

    ``mask``: (q,) float — 1.0 for workers whose gradient made the deadline (the
    trainer's straggler simulator or a real deadline monitor supplies it).
    """
    comp = comp or gradcomp.GradCompressionConfig(enabled=False)

    def local_grads(params, local_batch, key, mask_all):
        mask = mask_all[averaging.worker_index(axis_names)]

        def loss_fn(p):
            loss, aux = lm.lm_loss(p, cfg, local_batch, rules=None, plan=lm.ExecPlan(remat=remat))
            return loss, aux

        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        mean_grads = masked_compressed_mean(comp, key, grads, mask, axis_names)
        den = jnp.maximum(jax.lax.psum(mask, axis_names), 1.0)
        mean_loss = jax.lax.psum(loss * mask, axis_names) / den
        return mean_grads, mean_loss

    batch_spec = {"tokens": P(axis_names), "labels": P(axis_names), "loss_mask": P(axis_names)}
    smap = shard_map(
        local_grads,
        mesh=mesh,
        in_specs=(P(), batch_spec, P(), P()),
        out_specs=(P(), P()),
        check_vma=False,
    )

    @jax.jit
    def step(state, batch, key, mask):
        grads, loss = smap(state["params"], batch, key, mask)
        lr_scale = schedule(state["step"]) if schedule is not None else 1.0
        new_params, new_opt, om = adamw_update(
            opt_cfg, state["params"], grads, state["opt"], lr_scale=lr_scale
        )
        new_state = {"params": new_params, "opt": new_opt, "step": state["step"] + 1}
        return new_state, {"loss": loss, **om}

    return step
