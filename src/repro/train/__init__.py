"""Training substrate: state, jitted steps, trainer loop, sketch-DP integration."""
from repro.train.state import init_train_state, train_state_shapes, train_state_pspecs
from repro.train.step import make_train_step
from repro.train.trainer import Trainer, TrainerConfig
