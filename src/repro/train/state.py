"""Train state = {params, opt, step}: a plain pytree (checkpoint/shard friendly)."""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.distributed.sharding import ShardingRules, param_pspecs
from repro.models import lm
from repro.optim import AdamWConfig, init_opt_state

PyTree = Any


def init_train_state(cfg: ArchConfig, opt_cfg: AdamWConfig, key: jax.Array) -> PyTree:
    params = lm.init_params(cfg, key)
    return {
        "params": params,
        "opt": init_opt_state(opt_cfg, params),
        "step": jnp.zeros((), jnp.int32),
    }


def train_state_shapes(cfg: ArchConfig, opt_cfg: AdamWConfig) -> PyTree:
    """ShapeDtypeStruct tree — no allocation (dry-run / checkpoint manifests)."""
    return jax.eval_shape(lambda: init_train_state(cfg, opt_cfg, jax.random.PRNGKey(0)))


def train_state_pspecs(cfg: ArchConfig, opt_cfg: AdamWConfig, rules: ShardingRules) -> PyTree:
    """PartitionSpecs for the whole state: opt moments inherit their param's spec."""
    shapes = train_state_shapes(cfg, opt_cfg)
    pspecs = param_pspecs(shapes["params"], rules)
    return {
        "params": pspecs,
        "opt": {"mu": pspecs, "nu": pspecs, "count": P()},
        "step": P(),
    }


def train_state_shardings(cfg: ArchConfig, opt_cfg: AdamWConfig, mesh: Mesh, rules: ShardingRules):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        train_state_pspecs(cfg, opt_cfg, rules),
        is_leaf=lambda x: isinstance(x, P),
    )
