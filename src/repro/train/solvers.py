"""Sketched linear-head fitting: Algorithm 1 applied verbatim to LM features.

The paper's regression is dense least squares on a tall data matrix; inside an LM
framework the same problem appears whenever a linear map must be fit onto frozen
backbone features — classifier probes, value/reward heads, logit-lens calibrations,
or a cheap lm-head re-fit after vocabulary surgery. The feature matrix H (tokens ×
d_model) is exactly the paper's A (n ≫ d), so we fit with distributed sketch-and-solve
and inherit its straggler resilience and privacy accounting (features never leave the
master un-sketched when privacy mode is on).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import averaging, operators, privacy, sketches as sk, solve
from repro.utils import prng


def extract_features(params, cfg, batch, *, rules=None) -> jax.Array:
    """Frozen-backbone features: final-norm hidden states, flattened to (B·S, d)."""
    from repro.models import lm as lm_mod

    x, _, enc_out = lm_mod.embed_inputs(params, cfg, batch, rules=rules)
    h, _ = lm_mod.trunk(params, cfg, x, rules=rules, enc_out=enc_out, plan=lm_mod.ExecPlan(remat="none"))
    return h.reshape(-1, cfg.d_model).astype(jnp.float32)


def fit_head(
    key: jax.Array,
    H: jax.Array,
    Y: jax.Array,
    spec: sk.SketchSpec,
    *,
    q: int = 16,
    reg: float = 1e-4,
    straggler_mask: Optional[jax.Array] = None,
    accountant: Optional[privacy.PrivacyAccountant] = None,
) -> jax.Array:
    """Algorithm 1 on (H, Y): q sketch-and-solve workers (vmapped), masked average.

    Y may be (n,) or (n, k) (multi-output probe). Returns W (d,) or (d, k).
    """
    n = H.shape[0]
    if accountant is not None:
        gamma = float(jnp.std(H))
        for w in range(q):
            accountant.record(spec.m, n, gamma=gamma, tag=f"head-fit worker {w}")

    # All q workers' Grams in one fused batched pass over the feature matrix (the
    # master-sketch pattern): H is read once, S_kH never materialized — each worker
    # solve is then a d×d Cholesky on its (G_k, c_k).
    keys = prng.worker_keys(key, q)
    Gs, cs = operators.gram_batched(spec, keys, H, Y.reshape(n, -1))  # (q,d,d), (q,d,k)
    Ws = jax.vmap(lambda G, c: solve.lstsq_gram(G, c, reg=reg))(Gs, cs)  # (q, d, k)
    W = averaging.masked_average(Ws, straggler_mask)
    return W.reshape(H.shape[1:] + Y.shape[1:]) if Y.ndim > 1 else W[:, 0]


def head_fit_quality(H, Y, W) -> dict:
    """Residual diagnostics vs the exact solution (small problems / tests)."""
    Ym = Y.reshape(H.shape[0], -1)
    W_star = solve.lstsq(H, Ym, reg=1e-4)
    f = lambda w: float(jnp.sum((H @ w.reshape(H.shape[1], -1) - Ym) ** 2))
    fs, fw = f(W_star), f(W)
    return {"f_star": fs, "f_sketch": fw, "rel_err": (fw - fs) / max(fs, 1e-30)}
