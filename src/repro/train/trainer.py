"""Trainer loop: deterministic data, async checkpoints, crash-recovery, stragglers.

Fault-tolerance contract (what a 1000-node deployment needs, demonstrated at CPU
scale in tests):
  * **restart-determinism** — data batches are pure functions of (seed, step) and the
    PRNG state is derived from the step counter, so a job restored from step k replays
    bitwise the run that never crashed.
  * **crash-safe saves** — checkpoints are atomic (see checkpoint/store.py) and
    written asynchronously; ``Trainer.run`` recovers from the latest complete step on
    startup automatically.
  * **straggler simulation** — delegated to the runtime subsystem: give
    ``TrainerConfig.latency`` a seeded :class:`repro.runtime.LatencyModel` and every
    step draws one wave of per-worker runtimes (pure function of (latency.seed,
    worker, step) — restart-deterministic like everything else here), records it in
    a ``HeartbeatMonitor``, and passes the resulting on-time mask as a third
    argument to the step function (e.g. the sketch-DP step). ``straggler_report()``
    emits the monitor's extended schema (p50/p95, timeouts, effective q').
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.configs.base import ArchConfig
from repro.data import lm_batch
from repro.optim import AdamWConfig
from repro.train.state import init_train_state, train_state_shapes
from repro.train.step import make_train_step

PyTree = Any


@dataclasses.dataclass
class TrainerConfig:
    seed: int = 0
    batch: int = 8
    seq: int = 128
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    ckpt_keep: int = 3
    log_every: int = 10
    accum_steps: int = 1
    remat: str = "full"
    # straggler / failure injection (tests + demos)
    fail_at_step: Optional[int] = None
    # async-runtime delegation: a repro.runtime LatencyModel ⇒ each step samples a
    # (straggler_q,) runtime wave, and step_fn is called as step_fn(state, batch,
    # mask) — the step must accept the extra mask argument (sketch-DP style).
    latency: Optional[Any] = None
    straggler_q: int = 8
    deadline_s: float = 1.0


class Trainer:
    def __init__(
        self,
        cfg: ArchConfig,
        opt_cfg: AdamWConfig,
        tc: TrainerConfig,
        *,
        step_fn: Optional[Callable] = None,
        schedule: Optional[Callable] = None,
    ):
        self.cfg = cfg
        self.opt_cfg = opt_cfg
        self.tc = tc
        self.step_fn = jax.jit(
            step_fn
            or make_train_step(
                cfg, opt_cfg, schedule=schedule, remat=tc.remat, accum_steps=tc.accum_steps
            ),
            donate_argnums=(0,),
        )
        self.ckpt = AsyncCheckpointer(tc.ckpt_dir, keep=tc.ckpt_keep) if tc.ckpt_dir else None
        self.history: List[Dict[str, float]] = []
        self.monitor = None
        if tc.latency is not None:
            from repro.distributed.fault_tolerance import HeartbeatMonitor

            self.monitor = HeartbeatMonitor(q=tc.straggler_q, deadline=tc.deadline_s)

    # ------------------------------------------------------------------ state
    def init_or_restore(self) -> PyTree:
        state = None
        if self.tc.ckpt_dir:
            step = latest_step(self.tc.ckpt_dir)
            if step is not None:
                like = train_state_shapes(self.cfg, self.opt_cfg)
                state = restore_checkpoint(self.tc.ckpt_dir, step, like)
        if state is None:
            state = init_train_state(self.cfg, self.opt_cfg, jax.random.PRNGKey(self.tc.seed))
        return state

    def batch_for_step(self, step: int) -> Dict[str, jax.Array]:
        return lm_batch(
            self.tc.seed,
            step,
            batch=self.tc.batch,
            seq=self.tc.seq,
            vocab=self.cfg.vocab_size,
        )

    # ------------------------------------------------------------------ loop
    def run(self, steps: int, *, state: Optional[PyTree] = None) -> PyTree:
        state = state if state is not None else self.init_or_restore()
        s = int(state["step"])
        while s < steps:
            if self.tc.fail_at_step is not None and s == self.tc.fail_at_step:
                # simulate a node crash: drop the in-memory state entirely and
                # recover from the last complete checkpoint (restart-determinism
                # is asserted by tests comparing against an uninterrupted run).
                # The loop rewinds to the restored step and REPLAYS — deterministic
                # data makes the replay bitwise-equal to the uninterrupted run.
                if self.ckpt:
                    self.ckpt.wait()
                self.tc.fail_at_step = None
                state = self.init_or_restore()
                s = int(state["step"])
                continue
            batch = self.batch_for_step(s)
            if self.monitor is not None:
                # one runtime wave per step: runtimes are a pure function of
                # (latency.seed, worker, step), so a restarted job replays the
                # same straggler pattern it would have seen uninterrupted.
                wave = self.tc.latency.sample_wave(self.tc.straggler_q, round_id=s)
                mask = self.monitor.record_step(wave)
                self.monitor.record_timeout(int(self.tc.straggler_q - mask.sum()))
                state, metrics = self.step_fn(state, batch, jnp.asarray(mask))
            else:
                state, metrics = self.step_fn(state, batch)
            if s % self.tc.log_every == 0 or s == steps - 1:
                self.history.append({"step": s, **{k: float(v) for k, v in metrics.items()}})
            if self.ckpt and (s + 1) % self.tc.ckpt_every == 0:
                self.ckpt.save(s + 1, state)
            s += 1
        if self.ckpt:
            self.ckpt.save(steps, state)
            self.ckpt.wait()
        return state

    def straggler_report(self) -> Dict[str, float]:
        """Extended heartbeat schema (p50/p95, timeouts, effective q') for the run;
        empty when no latency model is configured."""
        return self.monitor.report() if self.monitor is not None else {}
