"""AdamW with decoupled weight decay, built from scratch on pytrees.

Moments inherit the *sharding of their parameters* automatically (they are created
with ``jnp.zeros_like`` inside the jitted update, so GSPMD assigns them the param
PartitionSpec) — this is ZeRO-style optimizer-state sharding for free whenever params
are fsdp/tensor-sharded. ``moment_dtype`` lets memory-pressed configs (grok-314b)
keep m/v in bf16: the classic 2× optimizer-memory production trick; the update math
still runs in f32.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.utils import tree as tu

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4                      # peak lr if a schedule is used
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0                # global-norm clip; 0 disables
    moment_dtype: str = "float32"         # "float32" | "bfloat16"
    # leaves whose path matches any of these substrings skip weight decay
    no_decay: Tuple[str, ...] = ("norm", "scale", "bias", "beta_a", "beta_s", "A_log", "D")


def _mdtype(cfg: AdamWConfig):
    return jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32


def init_opt_state(cfg: AdamWConfig, params: PyTree) -> PyTree:
    md = _mdtype(cfg)
    zeros = lambda p: jnp.zeros(p.shape, md)
    return {
        "mu": jax.tree_util.tree_map(zeros, params),
        "nu": jax.tree_util.tree_map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm_clip(grads: PyTree, max_norm: float) -> Tuple[PyTree, jax.Array]:
    """Scale the whole gradient tree so its global L2 norm is <= max_norm."""
    gnorm = tu.tree_global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-12))
    return jax.tree_util.tree_map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gnorm


def _path_has(path, needles) -> bool:
    s = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
    return any(n in s for n in needles)


def adamw_update(
    cfg: AdamWConfig,
    params: PyTree,
    grads: PyTree,
    opt_state: PyTree,
    *,
    lr_scale: jax.Array | float = 1.0,
) -> Tuple[PyTree, PyTree, dict]:
    """One AdamW step. ``lr_scale`` multiplies cfg.lr (schedules plug in here)."""
    if cfg.grad_clip > 0:
        grads, gnorm = global_norm_clip(grads, cfg.grad_clip)
    else:
        gnorm = tu.tree_global_norm(grads)
    count = opt_state["count"] + 1
    cf = count.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** cf
    bc2 = 1.0 - cfg.b2 ** cf
    lr = cfg.lr * lr_scale
    md = _mdtype(cfg)

    def upd(path, p, g, m, v):
        gf = g.astype(jnp.float32)
        mf = m.astype(jnp.float32) * cfg.b1 + gf * (1.0 - cfg.b1)
        vf = v.astype(jnp.float32) * cfg.b2 + gf * gf * (1.0 - cfg.b2)
        step = (mf / bc1) / (jnp.sqrt(vf / bc2) + cfg.eps)
        if cfg.weight_decay > 0 and not _path_has(path, cfg.no_decay):
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        return newp, mf.astype(md), vf.astype(md)

    out = jax.tree_util.tree_map_with_path(
        upd, params, grads, opt_state["mu"], opt_state["nu"]
    )
    # out is a tree of (p, m, v) tuples with the params' structure; unzip it.
    new_params = jax.tree_util.tree_map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree_util.tree_map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree_util.tree_map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    new_state = {"mu": new_mu, "nu": new_nu, "count": count}
    return new_params, new_state, {"grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}
