"""Optimizer substrate (from scratch — no optax in the container)."""
from repro.optim.adamw import AdamWConfig, init_opt_state, adamw_update, global_norm_clip
from repro.optim.schedules import (
    constant_schedule,
    linear_warmup_cosine,
    linear_schedule,
)
