"""Learning-rate schedules as pure step -> scale functions (multiply the peak lr)."""
from __future__ import annotations

import jax.numpy as jnp


def constant_schedule():
    return lambda step: jnp.ones((), jnp.float32)


def linear_schedule(total_steps: int, end_frac: float = 0.0):
    def f(step):
        t = jnp.minimum(step.astype(jnp.float32) / max(total_steps, 1), 1.0)
        return 1.0 + (end_frac - 1.0) * t

    return f


def linear_warmup_cosine(warmup_steps: int, total_steps: int, min_frac: float = 0.1):
    """Linear 0→1 over warmup, cosine 1→min_frac over the rest."""
    def f(step):
        s = step.astype(jnp.float32)
        warm = s / jnp.maximum(warmup_steps, 1)
        t = (s - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1)
        t = jnp.clip(t, 0.0, 1.0)
        cos = min_frac + (1.0 - min_frac) * 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return jnp.where(s < warmup_steps, warm, cos)

    return f
