"""Pytree utilities used across the framework."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_size(tree) -> int:
    """Total number of elements across all leaves."""
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree) -> int:
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize for x in jax.tree_util.tree_leaves(tree))


def tree_global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def tree_zeros_like(tree):
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


def tree_add(a, b):
    return jax.tree_util.tree_map(jnp.add, a, b)


def tree_scale(tree, s):
    return jax.tree_util.tree_map(lambda x: x * s, tree)


def tree_flatten_to_vector(tree) -> tuple[jax.Array, "TreeVectorizer"]:
    """Concatenate all leaves into one f32 vector, with an inverter."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    shapes = [x.shape for x in leaves]
    dtypes = [x.dtype for x in leaves]
    sizes = [int(np.prod(s)) for s in shapes]
    vec = jnp.concatenate([x.reshape(-1).astype(jnp.float32) for x in leaves]) if leaves else jnp.zeros((0,), jnp.float32)
    return vec, TreeVectorizer(treedef, shapes, dtypes, sizes)


class TreeVectorizer:
    """Inverse of :func:`tree_flatten_to_vector` (static metadata, jit-closable)."""

    def __init__(self, treedef, shapes, dtypes, sizes):
        self.treedef = treedef
        self.shapes = shapes
        self.dtypes = dtypes
        self.sizes = sizes
        self.total = sum(sizes)

    def unflatten(self, vec: jax.Array):
        leaves = []
        off = 0
        for shape, dtype, size in zip(self.shapes, self.dtypes, self.sizes):
            leaves.append(vec[off:off + size].reshape(shape).astype(dtype))
            off += size
        return jax.tree_util.tree_unflatten(self.treedef, leaves)
