from repro.utils import prng, tree
