# NOTE: no eager `prng` import here. repro.utils.prng imports repro.kernels.common,
# which imports repro.utils.env — an eager import would turn that chain into a
# cycle. `from repro.utils import prng` still works everywhere: python resolves
# submodule imports without the package __init__ naming them.
from repro.utils import env, tree
