"""Validated ``REPRO_*`` environment parsing — the one sanctioned env-read surface.

Every knob the library reads from the environment goes through this module. Two
reasons this is a hard rule (machine-checked by the ``env-read-in-trace``
reprolint rule, which flags ``os.environ`` / ``os.getenv`` anywhere else under
``repro/``):

  * **Trace capture.** Several knobs (``REPRO_RNG_ROUNDS``,
    ``REPRO_PALLAS_INTERPRET``) are resolved at *trace* time: the value is baked
    into the jit cache of whatever traces first. An ad-hoc read buried inside
    traced code makes that capture invisible; routing every read through here
    keeps the surface auditable and the resolution points documented.
  * **Validation.** A typo'd value must fail loudly, naming the variable — not
    silently fall back or raise a bare ``ValueError: invalid literal`` from
    somewhere deep in a trace.

This module is intentionally stdlib-only (no jax/numpy imports): it sits below
``repro.kernels.common`` in the import graph.
"""
from __future__ import annotations

import os

_TRUE = ("1", "true", "yes", "on")
_FALSE = ("0", "false", "no", "off")


def read_raw(name: str, default: str = "") -> str:
    """The stripped raw value of ``name`` (``default`` when unset)."""
    return os.environ.get(name, default).strip()


def read_bool(name: str, default: bool | None = None) -> bool | None:
    """Tri-state boolean: True/False when set, ``default`` when unset or empty.

    Accepts ``1/true/yes/on`` and ``0/false/no/off`` (case-insensitive); anything
    else raises a ``ValueError`` naming the variable.
    """
    raw = read_raw(name).lower()
    if not raw:
        return default
    if raw in _TRUE:
        return True
    if raw in _FALSE:
        return False
    raise ValueError(
        f"{name} must be a boolean flag ({'/'.join(_TRUE)} or {'/'.join(_FALSE)}), got {raw!r}"
    )


def read_int(
    name: str,
    default: int | None = None,
    *,
    positive: bool = False,
    multiple_of: int | None = None,
) -> int | None:
    """Integer knob: parsed value when set, ``default`` when unset or empty.

    A non-integer value, a non-positive value under ``positive=True``, or a value
    that is not a multiple of ``multiple_of`` all raise a ``ValueError`` naming
    the variable and the constraint.
    """
    raw = read_raw(name)
    if not raw:
        return default
    try:
        value = int(raw)
    except ValueError:
        raise ValueError(f"{name} must be an integer, got {raw!r}") from None
    constraint = None
    if positive and multiple_of is not None:
        constraint = f"a positive multiple of {multiple_of}"
        bad = value <= 0 or value % multiple_of
    elif positive:
        constraint = "a positive integer"
        bad = value <= 0
    elif multiple_of is not None:
        constraint = f"a multiple of {multiple_of}"
        bad = bool(value % multiple_of)
    else:
        bad = False
    if bad:
        raise ValueError(f"{name} must be {constraint}, got {value}")
    return value
