"""PRNG helpers.

All randomness in the framework flows through explicit jax PRNG keys. Workers derive
their keys by folding in a (worker_id, round) pair so that any worker can be restarted /
replaced and will regenerate exactly the same sketch — this is what makes the
sketch-and-solve workers true i.i.d. *stateless* copies of each other (the paper's
serverless model) and what makes checkpoint-restart deterministic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# One RNG source of truth: the counter-based primitives live with the Pallas
# kernels (repro.kernels.common) because the kernels must inline them; non-kernel
# code imports them from here so there is exactly one definition of each.
from repro.kernels.common import (  # noqa: F401  (re-exports)
    bits_to_open_unit,
    counter_normal,
    counter_rademacher,
    counter_rademacher_block,
)


def worker_key(base_key: jax.Array, worker_id: jax.Array | int, round_id: int = 0) -> jax.Array:
    """Deterministic per-(worker, round) key. Safe to call inside shard_map/vmap."""
    k = jax.random.fold_in(base_key, round_id)
    return jax.random.fold_in(k, worker_id)


def worker_keys(base_key: jax.Array, q: int, round_id: int = 0) -> jax.Array:
    """The (q,)-batched stack of ``worker_key(base_key, w, round_id)`` for w < q.

    Feed this to ``operators.apply_batched`` so the master computes all q workers'
    sketches in one pass; worker w of a shard_map'd mesh derives the identical key
    on its own — the two execution styles agree bit-for-bit.
    """
    return jax.vmap(lambda w: worker_key(base_key, w, round_id))(jnp.arange(q))


def split_tree(key: jax.Array, tree) -> "jax.tree_util.PyTreeDef":
    """One independent key per leaf of ``tree``, with the tree's structure."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))
    return jax.tree_util.tree_unflatten(treedef, list(keys))


def uniform_to_gaussian(u1: jax.Array, u2: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Box-Muller: two uniforms in (0,1) -> two independent standard normals."""
    r = jnp.sqrt(-2.0 * jnp.log(u1))
    theta = (2.0 * jnp.pi) * u2
    return r * jnp.cos(theta), r * jnp.sin(theta)
