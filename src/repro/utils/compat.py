"""Version-compat shims for jax APIs that moved between releases.

``shard_map`` lived in ``jax.experimental.shard_map`` through the 0.4/0.5 series and
was promoted to a top-level ``jax.shard_map`` later; the keyword controlling the
replication check was also renamed (``check_rep`` → ``check_vma``). Everything in
this repo imports :func:`shard_map` from here so exactly one place knows about the
difference.
"""
from __future__ import annotations

import inspect

try:  # jax >= 0.6: top-level export
    from jax import shard_map as _shard_map_impl
except ImportError:  # jax <= 0.5: experimental home
    from jax.experimental.shard_map import shard_map as _shard_map_impl

_PARAMS = set(inspect.signature(_shard_map_impl).parameters)
_CHECK_KW = "check_vma" if "check_vma" in _PARAMS else "check_rep"


def axis_size(name) -> int:
    """``jax.lax.axis_size`` for jax versions that predate it.

    Inside shard_map/pmap, the size of a named mesh axis. The ``psum(1)`` fallback
    is the classic idiom and constant-folds to the same static value.
    """
    import jax

    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool | None = None):
    """``jax.shard_map`` with a stable signature across jax versions.

    ``check_vma`` maps onto whichever of ``check_vma``/``check_rep`` the installed
    jax understands; ``None`` keeps the library default.
    """
    kwargs = {}
    if check_vma is not None:
        kwargs[_CHECK_KW] = check_vma
    return _shard_map_impl(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)
