"""Sketch-operator invariants: E[SᵀS] = I, shapes, scaling, determinism."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sketches as sk
from repro.utils import prng

KINDS_SIMPLE = ["gaussian", "srht", "uniform", "leverage", "sjlt"]


def _spec(kind, m, n):
    if kind == "hybrid":
        return sk.SketchSpec("hybrid", m, m_prime=min(2 * m, n), inner="gaussian")
    return sk.SketchSpec(kind, m)


@pytest.mark.parametrize("kind", KINDS_SIMPLE + ["hybrid"])
def test_shapes_and_determinism(kind):
    n, d, m = 64, 8, 32
    A = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    spec = _spec(kind, m, n)
    key = jax.random.PRNGKey(1)
    SA1 = sk.apply_sketch(spec, key, A)
    SA2 = sk.apply_sketch(spec, key, A)
    assert SA1.shape == (m, d)
    np.testing.assert_array_equal(np.asarray(SA1), np.asarray(SA2))
    SA3 = sk.apply_sketch(spec, jax.random.PRNGKey(2), A)
    assert not np.allclose(np.asarray(SA1), np.asarray(SA3))


@pytest.mark.parametrize("kind", KINDS_SIMPLE)
def test_identity_in_expectation(kind):
    """E[SᵀS] = I_n — the normalization all of the paper's lemmas assume."""
    n, m, trials = 24, 96, 300
    if kind == "leverage":
        # leverage scores need a concrete A; use a mildly non-uniform one
        A = jax.random.normal(jax.random.PRNGKey(5), (n, 6)) * jnp.linspace(0.5, 2.0, n)[:, None]
        scores = sk.leverage_scores(A)

        def one(i):
            key = prng.worker_key(jax.random.PRNGKey(0), i)
            S = sk.leverage_sketch(key, jnp.eye(n), m, scores=scores)
            return S.T @ S
    else:
        spec = _spec(kind, m, n)

        def one(i):
            key = prng.worker_key(jax.random.PRNGKey(0), i)
            S = sk.materialize(spec, key, n)
            return S.T @ S

    G = jnp.mean(jax.lax.map(one, jnp.arange(trials), batch_size=32), axis=0)
    err = float(jnp.max(jnp.abs(G - jnp.eye(n))))
    # MC error ~ 1/sqrt(trials·m); generous envelope
    assert err < 0.35, (kind, err)


def test_sketch_data_same_S():
    """(SA, Sb) must use the same S (Algorithm 1): sketching [A|b] jointly."""
    n, d, m = 128, 8, 32
    A = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    b = jax.random.normal(jax.random.PRNGKey(1), (n,))
    spec = sk.SketchSpec("gaussian", m)
    key = jax.random.PRNGKey(2)
    SA, Sb = sk.sketch_data(spec, key, A, b)
    S = sk.materialize(spec, key, n)
    np.testing.assert_allclose(np.asarray(SA), np.asarray(S @ A), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(Sb), np.asarray(S @ b), rtol=1e-4, atol=1e-4)


def test_srht_orthogonality_exact():
    """For m = n_pad = n, SRHT is orthogonal-up-to-sampling: SᵀS has E=I but each
    realization satisfies ‖Sx‖ concentrated; check the Hadamard core is orthonormal."""
    n = 64
    x = jax.random.normal(jax.random.PRNGKey(0), (n, 3))
    from repro.core.sketches import _fwht

    Hx = _fwht(x)
    np.testing.assert_allclose(
        np.asarray(_fwht(Hx)) / n, np.asarray(x), rtol=1e-5, atol=1e-5
    )


def test_uniform_without_replacement_no_duplicates():
    n, m = 64, 32
    key = jax.random.PRNGKey(0)
    S = sk.materialize(sk.SketchSpec("uniform", m, replacement=False), key, n)
    rows = np.asarray(jnp.argmax(jnp.abs(S), axis=1))
    assert len(set(rows.tolist())) == m


def test_leverage_scores_sum_to_rank():
    A = jax.random.normal(jax.random.PRNGKey(0), (50, 7))
    for method in ("qr", "svd", "approx"):
        s = sk.leverage_scores(A, method=method)
        assert abs(float(jnp.sum(s)) - 7.0) < (0.05 if method != "approx" else 0.8)


def test_sjlt_column_sparsity():
    n, m, s = 32, 16, 3
    S = sk.materialize(sk.SketchSpec("sjlt", m, s=s), jax.random.PRNGKey(0), n)
    nnz_per_col = np.asarray((np.abs(np.asarray(S)) > 0).sum(axis=0))
    assert (nnz_per_col <= s).all()  # collisions may merge buckets
    assert (nnz_per_col >= 1).all()


def test_hybrid_reduces_to_extremes():
    """m'=m -> plain sampling row-set; m'=n with gaussian inner ~ gaussian sketch."""
    n, d, m = 64, 6, 16
    A = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    spec = sk.SketchSpec("hybrid", m, m_prime=m, inner="gaussian")
    SA = sk.apply_sketch(spec, jax.random.PRNGKey(1), A)
    assert SA.shape == (m, d)
    spec_full = sk.SketchSpec("hybrid", m, m_prime=n, inner="gaussian")
    SA2 = sk.apply_sketch(spec_full, jax.random.PRNGKey(1), A)
    assert SA2.shape == (m, d)
