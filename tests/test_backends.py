"""Executor backend layer: crash fault-injection, pickling, factory contracts.

The process-backend tests SIGKILL real worker processes via
:class:`repro.runtime.backends.KillSwitch` and pin the recovery story end to end:
a killed worker surfaces as a ``drop`` event, re-enters deadline→backoff→retry
with a fresh round-folded key, and innocent pool-mates (whose futures the broken
pool also poisoned) are transparently re-run and never appear in the event log.
"""
import pickle

import jax
import numpy as np
import pytest

from repro import runtime as rt
from repro.core import sketches as sk, solve
from repro.utils import prng


def _toy_problem(n=256, d=8):
    key = jax.random.PRNGKey(0)
    A = jax.random.normal(key, (n, d))
    b = A @ jax.random.normal(jax.random.PRNGKey(1), (d,)) + 0.1 * jax.random.normal(
        jax.random.PRNGKey(2), (n,)
    )
    return key, A, b


# ------------------------------------------------------------------ quick (no pools)


def test_make_backend_rejects_unknown_kind():
    with pytest.raises(ValueError, match="unknown backend"):
        rt.make_backend("quantum", lambda w, r: np.zeros(2))


def test_make_backend_passes_instances_through():
    inline = rt.InlineBackend(lambda w, r: np.zeros(2))
    assert rt.make_backend(inline, lambda w, r: np.ones(2)) is inline
    assert set(rt.BACKENDS) == {"inline", "thread", "process"}


def test_sketch_solve_compute_pickle_roundtrip():
    """The process backend ships the compute by pickle; the clone must produce
    bitwise-identical results (numpy state, jit rebuilt lazily on the far side)."""
    key, A, b = _toy_problem()
    compute = rt.make_sketch_solve_compute(sk.SketchSpec("gaussian", 64), key, A, b)
    clone = pickle.loads(pickle.dumps(compute))
    np.testing.assert_array_equal(compute(1, 0), clone(1, 0))
    np.testing.assert_array_equal(compute(0, 3), clone(0, 3))


def test_least_norm_compute_pickle_roundtrip():
    key = jax.random.PRNGKey(0)
    A = jax.random.normal(key, (8, 64))  # n < d: the §V right-sketch regime
    b = jax.random.normal(jax.random.PRNGKey(1), (8,))
    compute = rt.make_least_norm_compute(sk.SketchSpec("gaussian", 32), key, A, b)
    clone = pickle.loads(pickle.dumps(compute))
    np.testing.assert_array_equal(compute(2, 1), clone(2, 1))


def test_kill_switch_refuses_to_kill_master():
    """On inline/thread the task runs in the master process — KillSwitch must
    refuse rather than SIGKILL the test runner."""
    ks = rt.KillSwitch(inner=lambda w, r: np.zeros(2), kill_coords=((0, 0),))
    with pytest.raises(RuntimeError, match="master process"):
        ks(0, 0)
    np.testing.assert_array_equal(ks(1, 0), np.zeros(2))  # non-matching coords run


# --------------------------------------------------------- crash → drop → retry


def _kill_engine(kill_coords, *, q=2, max_retries=2, latency_seed=0):
    key, A, b = _toy_problem()
    spec = sk.SketchSpec("gaussian", 64)
    compute = rt.KillSwitch(
        inner=rt.make_sketch_solve_compute(spec, key, A, b), kill_coords=kill_coords
    )
    cfg = rt.RuntimeConfig(
        deadline_s=1.0, max_retries=max_retries, backoff_base_s=0.05, max_threads=2
    )
    lat = rt.ConstantLatency(seed=latency_seed, value_s=0.1)
    eng = rt.ServerlessEngine(compute, lat, cfg, backend="process")
    return key, A, b, spec, eng


@pytest.mark.slow
@pytest.mark.subprocess
def test_process_crash_drops_then_retries_with_fresh_key():
    """SIGKILL at (worker 0, round 0): the engine hears a drop, retries with a
    fresh round id, and the retry lands — the acceptance scenario."""
    key, A, b, spec, eng = _kill_engine(kill_coords=((0, 0),))
    res = eng.run(q=2)

    counts = res.events.counts()
    assert counts.get("drop", 0) == 1
    assert counts.get("retry", 0) == 1
    assert counts.get("timeout", 0) == 0
    assert res.count == 2 and res.dispatched == 3
    # the innocent pool-mate (worker 1) arrived normally, untouched by the crash
    assert (1, 0, 0) in res.arrived
    drops = [ev for ev in res.events if ev.kind == "drop"]
    assert [(ev.worker_id, ev.round_id) for ev in drops] == [(0, 0)]
    # the retry carries a *fresh* round (never a replay of the killed coordinate)
    assert (0, 1, 1) in res.arrived
    assert res.summary(deadline=1.0)["drops"] == 1

    # x̄ is the plain mean over exactly the arrived (worker, round) keys
    xs = np.stack(
        [
            np.asarray(solve.sketch_and_solve(spec, prng.worker_key(key, w, r), A, b))
            for (w, r, _) in res.arrived
        ]
    )
    np.testing.assert_allclose(res.xbar, xs.mean(0), rtol=1e-6, atol=1e-6)


@pytest.mark.slow
@pytest.mark.subprocess
def test_process_crash_without_retry_budget_just_drops():
    """max_retries=0: the crashed task is simply lost; the average is over the
    survivors and realized_mask records who made it."""
    _, _, _, _, eng = _kill_engine(kill_coords=((0, 0),), max_retries=0)
    res = eng.run(q=2)
    assert res.count == 1
    assert res.events.counts().get("drop", 0) == 1
    assert "retry" not in res.events.counts()
    np.testing.assert_array_equal(res.realized_mask, np.asarray([0.0, 1.0], np.float32))


@pytest.mark.slow
@pytest.mark.subprocess
def test_process_repeated_crashes_exhaust_budget_and_raise():
    """A task whose every attempt is killed (rounds 0,1,2 for worker 0 with
    q=1) exhausts max_retries and, with no other workers, x̄ is undefined."""
    _, _, _, _, eng = _kill_engine(kill_coords=((0, 0), (0, 1), (0, 2)), q=1)
    with pytest.raises(RuntimeError, match="no worker result"):
        eng.run(q=1)
