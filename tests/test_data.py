"""Data pipeline: determinism, shard/row disjointness, learnability structure."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data import (
    airline_like,
    emnist_like,
    gaussian_regression,
    lm_batch,
    lm_eval_batch,
    student_t_regression,
)


def test_lm_batch_deterministic_and_step_dependent():
    a = lm_batch(0, 3, batch=4, seq=32, vocab=97)
    b = lm_batch(0, 3, batch=4, seq=32, vocab=97)
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    c = lm_batch(0, 4, batch=4, seq=32, vocab=97)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))
    assert int(a["tokens"].max()) < 97 and int(a["tokens"].min()) >= 0


def test_lm_batch_row_offset_shards_disjoint():
    """Two shards of the same global batch must produce different rows, and
    regenerating a shard (worker replacement) must be bitwise identical."""
    s0 = lm_batch(0, 1, batch=2, seq=16, vocab=97, row_offset=0)
    s1 = lm_batch(0, 1, batch=2, seq=16, vocab=97, row_offset=2)
    full = lm_batch(0, 1, batch=4, seq=16, vocab=97)
    np.testing.assert_array_equal(np.asarray(full["tokens"][:2]), np.asarray(s0["tokens"]))
    np.testing.assert_array_equal(np.asarray(full["tokens"][2:]), np.asarray(s1["tokens"]))


def test_eval_split_disjoint():
    tr = lm_batch(0, 0, batch=4, seq=16, vocab=97)
    ev = lm_eval_batch(0, 0, batch=4, seq=16, vocab=97)
    assert not np.array_equal(np.asarray(tr["tokens"]), np.asarray(ev["tokens"]))


def test_lm_batch_has_learnable_bigram_structure():
    b = lm_batch(0, 0, batch=16, seq=128, vocab=53, p_pattern=0.9)
    toks = np.asarray(b["tokens"])
    a, c = 31337 % 53, 7919 % 53
    pred = (a * toks[:, :-1] + c) % 53
    frac = (pred == toks[:, 1:]).mean()
    assert frac > 0.8, frac  # ~p_pattern of transitions follow the affine map


def test_regression_generators():
    A, b, meta = gaussian_regression(jax.random.PRNGKey(0), 128, 8)
    assert A.shape == (128, 8) and b.shape == (128,)
    A, b, meta = student_t_regression(jax.random.PRNGKey(0), 128, 8, df=1.5)
    assert np.isfinite(np.asarray(A)).all()
    A, b, meta = airline_like(jax.random.PRNGKey(0), 256)
    assert A.shape == (256, meta["d"])
    assert set(np.unique(np.asarray(b))) <= {0.0, 1.0}
    A, B, meta = emnist_like(jax.random.PRNGKey(0), 64, classes=5, img_dim=16)
    assert B.shape == (64, 5)
    np.testing.assert_allclose(np.asarray(B.sum(axis=1)), 1.0)
