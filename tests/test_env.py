"""repro.utils.env — the one sanctioned, validated env-read surface."""
from __future__ import annotations

import pytest

from repro.utils import env


def test_read_raw_strips_and_defaults(monkeypatch):
    monkeypatch.setenv("REPRO_TEST_RAW", "  hello ")
    assert env.read_raw("REPRO_TEST_RAW") == "hello"
    monkeypatch.delenv("REPRO_TEST_RAW", raising=False)
    assert env.read_raw("REPRO_TEST_RAW", "fallback") == "fallback"


@pytest.mark.parametrize("raw,expected", [
    ("1", True), ("true", True), ("YES", True), ("on", True),
    ("0", False), ("False", False), ("no", False), ("OFF", False),
])
def test_read_bool_accepts_both_spellings(monkeypatch, raw, expected):
    monkeypatch.setenv("REPRO_TEST_FLAG", raw)
    assert env.read_bool("REPRO_TEST_FLAG") is expected


def test_read_bool_tristate_default(monkeypatch):
    monkeypatch.delenv("REPRO_TEST_FLAG", raising=False)
    assert env.read_bool("REPRO_TEST_FLAG") is None
    assert env.read_bool("REPRO_TEST_FLAG", True) is True
    monkeypatch.setenv("REPRO_TEST_FLAG", "")
    assert env.read_bool("REPRO_TEST_FLAG", False) is False


def test_read_bool_rejects_garbage_naming_the_variable(monkeypatch):
    monkeypatch.setenv("REPRO_TEST_FLAG", "maybe")
    with pytest.raises(ValueError, match="REPRO_TEST_FLAG must be a boolean flag"):
        env.read_bool("REPRO_TEST_FLAG")


def test_read_int_parses_and_defaults(monkeypatch):
    monkeypatch.setenv("REPRO_TEST_N", "24")
    assert env.read_int("REPRO_TEST_N") == 24
    monkeypatch.delenv("REPRO_TEST_N", raising=False)
    assert env.read_int("REPRO_TEST_N", 8) == 8


@pytest.mark.parametrize("raw,fragment", [
    ("x", "must be an integer, got 'x'"),
    ("0", "must be a positive multiple of 4, got 0"),
    ("-4", "must be a positive multiple of 4, got -4"),
    ("6", "must be a positive multiple of 4, got 6"),
])
def test_read_int_constraint_errors_name_variable(monkeypatch, raw, fragment):
    monkeypatch.setenv("REPRO_TEST_N", raw)
    with pytest.raises(ValueError) as e:
        env.read_int("REPRO_TEST_N", positive=True, multiple_of=4)
    assert "REPRO_TEST_N" in str(e.value) and fragment in str(e.value)


def test_read_int_positive_only(monkeypatch):
    monkeypatch.setenv("REPRO_TEST_N", "-1")
    with pytest.raises(ValueError, match="must be a positive integer"):
        env.read_int("REPRO_TEST_N", positive=True)


def test_kernel_knobs_route_through_env_surface(monkeypatch):
    """The real consumers (kernels.common) honor the validated surface."""
    from repro.kernels import common

    monkeypatch.setenv("REPRO_RNG_ROUNDS", "12")
    assert common.rng_rounds() == 12
    monkeypatch.setenv("REPRO_RNG_ROUNDS", "6")
    with pytest.raises(ValueError, match="REPRO_RNG_ROUNDS"):
        common.rng_rounds()
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    assert common.default_interpret() is True
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    assert common.default_interpret() is False
