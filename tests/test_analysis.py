"""reprolint unit tests: one true-positive + one near-miss negative per rule,
suppression comments, baseline round-trip, and CLI end-to-end injection runs.

Fixture snippets are analyzed at *virtual* paths (``src/repro/runtime/...``)
so each rule's path scoping is exercised without touching the real tree.
"""
from __future__ import annotations

import json
import textwrap

import pytest

from repro.analysis import Baseline, analyze_source, parse_source, rule_names
from repro.analysis.cli import main as lint_main
from repro.analysis.engine import check_module, run as lint_run
from repro.analysis.registry import all_rules

RUNTIME = "src/repro/runtime/engine.py"
CORE = "src/repro/core/sketches.py"
LAUNCH = "src/repro/launch/serve.py"


def findings(source, path, rule=None):
    out = analyze_source(textwrap.dedent(source), path)
    if rule is not None:
        out = [f for f in out if f.rule == rule]
    return out


def test_rule_registry_is_complete():
    assert set(rule_names()) == {
        "rng-key-reuse",
        "wallclock-in-runtime",
        "trace-hazard",
        "env-read-in-trace",
        "unpicklable-task-spec",
    }
    with pytest.raises(KeyError):
        all_rules(["no-such-rule"])


# --------------------------------------------------------------- rng-key-reuse


def test_rng_key_reuse_two_draws():
    found = findings(
        """
        import jax

        def two_draws(key, n):
            a = jax.random.normal(key, (n,))
            b = jax.random.uniform(key, (n,))
            return a + b
        """,
        CORE,
        "rng-key-reuse",
    )
    assert len(found) == 1
    assert found[0].line == 6
    assert "`key`" in found[0].message


def test_rng_key_reuse_across_loop_iterations():
    found = findings(
        """
        import jax

        def per_round(key, q):
            outs = []
            for r in range(q):
                outs.append(jax.random.normal(key, (4,)))
            return outs
        """,
        CORE,
        "rng-key-reuse",
    )
    assert len(found) == 1


def test_rng_fold_in_per_iteration_is_clean():
    assert not findings(
        """
        import jax

        def per_round(key, q):
            outs = []
            for r in range(q):
                kr = jax.random.fold_in(key, r)
                outs.append(jax.random.normal(kr, (4,)))
            return outs
        """,
        CORE,
        "rng-key-reuse",
    )


def test_rng_split_then_draw_is_clean():
    assert not findings(
        """
        import jax

        def split_draw(key):
            k1, k2 = jax.random.split(key)
            a = jax.random.normal(k1, (2,))
            b = jax.random.normal(k2, (2,))
            return a + b
        """,
        CORE,
        "rng-key-reuse",
    )


def test_rng_exclusive_branches_are_clean():
    assert not findings(
        """
        import jax

        def branchy(key, flag):
            if flag:
                x = jax.random.normal(key, (2,))
            else:
                x = jax.random.uniform(key, (2,))
            return x
        """,
        CORE,
        "rng-key-reuse",
    )


def test_rng_sketch_consumer_counts_as_draw():
    found = findings(
        """
        import jax
        from repro.core.solve import sketch_and_solve

        def solve_twice(spec, key, A, b):
            x1 = sketch_and_solve(spec, key, A, b)
            x2 = sketch_and_solve(spec, key, A, b)
            return x1, x2
        """,
        CORE,
        "rng-key-reuse",
    )
    assert len(found) == 1


def test_rng_rule_skips_tests():
    assert not findings(
        """
        import jax

        def parity(key):
            return jax.random.normal(key, (2,)), jax.random.normal(key, (2,))
        """,
        "tests/test_parity.py",
        "rng-key-reuse",
    )


# --------------------------------------------------------- wallclock-in-runtime


def test_wallclock_in_runtime_is_strict():
    found = findings(
        """
        import time
        from repro.analysis import sanctioned_wall_timer

        @sanctioned_wall_timer
        def deadline():
            return time.time() + 1.0
        """,
        RUNTIME,
        "wallclock-in-runtime",
    )
    # the decorator is deliberately NOT honored under runtime/
    assert len(found) == 1
    assert "simulated clock" in found[0].message


def test_wallclock_sanctioned_in_launch_is_clean():
    src = """
    import time
    from repro.analysis import sanctioned_wall_timer

    @sanctioned_wall_timer
    def report():
        t0 = time.perf_counter()
        return time.perf_counter() - t0
    """
    assert not findings(src, LAUNCH, "wallclock-in-runtime")
    # same code without the decorator is a finding
    bare = src.replace("    @sanctioned_wall_timer\n", "")
    assert len(findings(bare, LAUNCH, "wallclock-in-runtime")) == 2


def test_wallclock_aliased_import_detected():
    found = findings(
        """
        from time import perf_counter as clock

        def tick():
            return clock()
        """,
        RUNTIME,
        "wallclock-in-runtime",
    )
    assert len(found) == 1


def test_wallclock_ignores_unchecked_surfaces():
    assert not findings(
        """
        import time

        def tick():
            return time.time()
        """,
        "src/repro/data/loader.py",
        "wallclock-in-runtime",
    )


# ----------------------------------------------------------------- trace-hazard


def test_trace_hazard_python_if_on_traced_value():
    found = findings(
        """
        import jax

        @jax.jit
        def relu(x):
            if x > 0:
                return x
            return -x
        """,
        CORE,
        "trace-hazard",
    )
    assert len(found) == 1


def test_trace_hazard_host_sync_in_jit():
    found = findings(
        """
        import jax

        @jax.jit
        def bad(x):
            return float(x) * 2
        """,
        CORE,
        "trace-hazard",
    )
    assert len(found) == 1


def test_trace_hazard_static_param_branch_is_clean():
    assert not findings(
        """
        import jax

        @jax.jit
        def f(x, n: int):
            if n > 2:
                return x * n
            return x
        """,
        CORE,
        "trace-hazard",
    )


def test_trace_hazard_shape_access_is_clean():
    assert not findings(
        """
        import jax

        @jax.jit
        def f(x):
            if x.ndim > 1 and len(x.shape) > 1:
                return x.sum(axis=0)
            return x
        """,
        CORE,
        "trace-hazard",
    )


def test_trace_hazard_lru_cache_on_array_returning_fn():
    found = findings(
        """
        import functools
        import jax.numpy as jnp

        @functools.lru_cache(maxsize=None)
        def hadamard(n):
            return jnp.ones((n, n))
        """,
        CORE,
        "trace-hazard",
    )
    assert len(found) == 1
    assert "lru_cache" in found[0].message


def test_trace_hazard_lru_cache_on_scalar_fn_is_clean():
    assert not findings(
        """
        import functools

        @functools.lru_cache(maxsize=None)
        def next_pow2(n):
            m = 1
            while m < n:
                m *= 2
            return m
        """,
        CORE,
        "trace-hazard",
    )


# ------------------------------------------------------------ env-read-in-trace


def test_env_read_flagged_outside_sanctioned_module():
    found = findings(
        """
        import os

        def rounds():
            return int(os.environ.get("REPRO_RNG_ROUNDS", "20"))
        """,
        "src/repro/kernels/common.py",
        "env-read-in-trace",
    )
    assert len(found) == 1
    assert "repro.utils.env" in found[0].message


def test_env_read_allowed_in_utils_env():
    assert not findings(
        """
        import os

        def read_raw(name):
            return os.environ.get(name)
        """,
        "src/repro/utils/env.py",
        "env-read-in-trace",
    )


def test_env_write_is_not_a_read():
    assert not findings(
        """
        import os

        def force_interpret():
            os.environ["REPRO_PALLAS_INTERPRET"] = "1"
        """,
        "src/repro/kernels/common.py",
        "env-read-in-trace",
    )


# -------------------------------------------------------- unpicklable-task-spec


def test_pickle_spec_lambda_field():
    found = findings(
        """
        class _PicklableCompute:
            pass

        class BadSpec(_PicklableCompute):
            def __init__(self, shift):
                self.fn = lambda x: x + shift
        """,
        "src/repro/runtime/tasks.py",
        "unpicklable-task-spec",
    )
    assert len(found) == 1
    assert "lambda" in found[0].message


def test_pickle_spec_lock_and_jax_array_fields():
    found = findings(
        """
        import threading
        import jax.numpy as jnp

        class _PicklableCompute:
            pass

        class WorseSpec(_PicklableCompute):
            def __init__(self, n):
                self.lock = threading.Lock()
                self.data = jnp.ones((n,))
        """,
        "src/repro/runtime/tasks.py",
        "unpicklable-task-spec",
    )
    assert len(found) == 2


def test_pickle_spec_numpy_fields_are_clean():
    assert not findings(
        """
        import numpy as np

        class _PicklableCompute:
            pass

        class GoodSpec(_PicklableCompute):
            def __init__(self, n):
                self.data = np.ones((n,))
                self.n = int(n)
        """,
        "src/repro/runtime/tasks.py",
        "unpicklable-task-spec",
    )


def test_pickle_spec_transitive_subclass_checked():
    found = findings(
        """
        class _PicklableCompute:
            pass

        class MidSpec(_PicklableCompute):
            pass

        class LeafSpec(MidSpec):
            def __init__(self):
                self.fn = lambda: 0
        """,
        "src/repro/runtime/tasks.py",
        "unpicklable-task-spec",
    )
    assert len(found) == 1


def test_pickle_spec_plain_class_not_checked():
    assert not findings(
        """
        class NotASpec:
            def __init__(self):
                self.fn = lambda: 0
        """,
        "src/repro/runtime/tasks.py",
        "unpicklable-task-spec",
    )


# ----------------------------------------------------------------- suppressions


def test_same_line_suppression_swallows_finding():
    src = textwrap.dedent(
        """
        import time

        def deadline():
            return time.time()  # reprolint: disable=wallclock-in-runtime
        """
    )
    assert not findings(src, RUNTIME, "wallclock-in-runtime")
    # ...but the engine still counts it, so suppressions stay visible
    module = parse_source(src, RUNTIME)
    _, suppressed = check_module(module, all_rules())
    assert suppressed == 1


def test_suppression_is_rule_specific():
    src = textwrap.dedent(
        """
        import time

        def deadline():
            return time.time()  # reprolint: disable=rng-key-reuse
        """
    )
    assert len(findings(src, RUNTIME, "wallclock-in-runtime")) == 1


def test_disable_all_suppresses_everything():
    src = textwrap.dedent(
        """
        import time

        def deadline():
            return time.time()  # reprolint: disable=all
        """
    )
    assert not findings(src, RUNTIME)


# --------------------------------------------------------------------- baseline


def _write_tree(root, rel, source):
    p = root / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(source))
    return p


BAD_RUNTIME = """
import time

def deadline():
    return time.time()
"""


def test_baseline_round_trip(tmp_path):
    _write_tree(tmp_path, "src/repro/runtime/engine.py", BAD_RUNTIME)
    report = lint_run([str(tmp_path / "src")])
    assert len(report.new) == 1 and report.exit_code == 1

    baseline_path = tmp_path / "reprolint-baseline.json"
    Baseline.from_findings(report.new, report.snippets).save(str(baseline_path))
    reloaded = Baseline.load(str(baseline_path))
    assert len(reloaded) == 1

    again = lint_run([str(tmp_path / "src")], baseline=reloaded)
    assert again.exit_code == 0
    assert not again.new and len(again.grandfathered) == 1


def test_baseline_survives_line_drift_but_not_duplication(tmp_path):
    _write_tree(tmp_path, "src/repro/runtime/engine.py", BAD_RUNTIME)
    report = lint_run([str(tmp_path / "src")])
    baseline = Baseline.from_findings(report.new, report.snippets)

    # push the finding two lines down: fingerprint is line-content based
    _write_tree(tmp_path, "src/repro/runtime/engine.py", "\n\n" + BAD_RUNTIME)
    assert lint_run([str(tmp_path / "src")], baseline=baseline).exit_code == 0

    # a second copy of the same bug is NOT covered by the one baseline entry
    dup = BAD_RUNTIME + "\n\ndef deadline2():\n    return time.time()\n"
    _write_tree(tmp_path, "src/repro/runtime/engine.py", dup)
    report = lint_run([str(tmp_path / "src")], baseline=baseline)
    assert report.exit_code == 1
    assert len(report.new) == 1 and len(report.grandfathered) == 1


def test_baseline_missing_file_is_empty(tmp_path):
    assert len(Baseline.load(str(tmp_path / "absent.json"))) == 0


# ------------------------------------------------------------- CLI end-to-end


def test_cli_injected_wallclock_fails(tmp_path, capsys):
    _write_tree(tmp_path, "src/repro/runtime/engine.py", BAD_RUNTIME)
    rc = lint_main([str(tmp_path / "src"), "--no-baseline"])
    out = capsys.readouterr().out
    assert rc == 1
    assert "wallclock-in-runtime" in out
    assert "engine.py:5" in out


def test_cli_injected_key_reuse_fails(tmp_path, capsys):
    _write_tree(
        tmp_path,
        "src/repro/core/sketchy.py",
        """
        import jax

        def two_draws(key):
            a = jax.random.normal(key, (2,))
            b = jax.random.normal(key, (2,))
            return a + b
        """,
    )
    rc = lint_main([str(tmp_path / "src"), "--no-baseline"])
    out = capsys.readouterr().out
    assert rc == 1
    assert "rng-key-reuse" in out
    assert "sketchy.py:6" in out


def test_cli_injected_lambda_spec_fails(tmp_path, capsys):
    _write_tree(
        tmp_path,
        "src/repro/runtime/tasks.py",
        """
        class _PicklableCompute:
            pass

        class BadSpec(_PicklableCompute):
            def __init__(self):
                self.fn = lambda: 0
        """,
    )
    rc = lint_main([str(tmp_path / "src"), "--no-baseline"])
    out = capsys.readouterr().out
    assert rc == 1
    assert "unpicklable-task-spec" in out
    assert "tasks.py:7" in out


def test_cli_clean_tree_exits_zero(tmp_path, capsys):
    _write_tree(tmp_path, "src/repro/core/ok.py", "X = 1\n")
    rc = lint_main([str(tmp_path / "src"), "--no-baseline"])
    assert rc == 0
    assert "clean" in capsys.readouterr().out


def test_cli_write_baseline_then_gate(tmp_path, capsys):
    _write_tree(tmp_path, "src/repro/runtime/engine.py", BAD_RUNTIME)
    baseline = tmp_path / "reprolint-baseline.json"
    assert lint_main([str(tmp_path / "src"), "--baseline", str(baseline), "--write-baseline"]) == 0
    data = json.loads(baseline.read_text())
    assert data["version"] == 1 and len(data["entries"]) == 1
    capsys.readouterr()
    assert lint_main([str(tmp_path / "src"), "--baseline", str(baseline)]) == 0
    assert "1 baselined" in capsys.readouterr().out


def test_cli_json_report(tmp_path, capsys):
    _write_tree(tmp_path, "src/repro/runtime/engine.py", BAD_RUNTIME)
    rc = lint_main([str(tmp_path / "src"), "--no-baseline", "--json"])
    payload = json.loads(capsys.readouterr().out)
    assert rc == 1
    assert payload["new"][0]["rule"] == "wallclock-in-runtime"


def test_cli_parse_error_exits_two(tmp_path, capsys):
    _write_tree(tmp_path, "src/repro/core/broken.py", "def oops(:\n")
    rc = lint_main([str(tmp_path / "src"), "--no-baseline"])
    assert rc == 2
    assert "parse error" in capsys.readouterr().err


def test_cli_unknown_rule_exits_two(capsys):
    assert lint_main(["--select", "bogus-rule"]) == 2
    assert "bogus-rule" in capsys.readouterr().err
