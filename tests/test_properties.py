"""Property tests on system invariants.

Runs under real hypothesis when installed (the ``test`` extra) and under the
deterministic fallback in ``tests/_hypo.py`` otherwise — never skipped.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypo import given, settings, st

from repro.core import averaging, privacy, sketches as sk
from repro.kernels import common as kcommon
from repro.models import layers
from repro.utils import tree as tu

jax.config.update("jax_enable_x64", False)
FAST = settings(max_examples=20, deadline=None)

# ~a minute of many-shape jit compiles: tier-1 runs it, test.sh --fast skips it
pytestmark = pytest.mark.slow


@FAST
@given(
    n=st.integers(8, 128),
    d=st.integers(1, 16),
    kind=st.sampled_from(["gaussian", "rademacher", "uniform", "sjlt", "srht"]),
    seed=st.integers(0, 2**20),
)
def test_sketch_shape_contract(n, d, kind, seed):
    m = max(4, n // 2)
    A = jax.random.normal(jax.random.PRNGKey(seed), (n, d))
    SA = sk.apply_sketch(sk.SketchSpec(kind, m), jax.random.PRNGKey(seed + 1), A)
    assert SA.shape == (m, d)
    assert bool(jnp.isfinite(SA).all())


@FAST
@given(
    kind=st.sampled_from(["gaussian", "rademacher"]),
    n=st.integers(64, 256),
    seed=st.integers(0, 2**20),
)
def test_subgaussian_embedding_quality(kind, n, seed):
    """‖S y‖² concentrates around ‖y‖² for the dense sub-gaussian families — the
    JL/embedding property the paper's averaging analysis rests on. m = 512 keeps
    the relative deviation ~1/√m, so the loose factor-of-2 bounds are safe."""
    m = 512
    y = jax.random.normal(jax.random.PRNGKey(seed), (n,))
    Sy = sk.apply_sketch(sk.SketchSpec(kind, m), jax.random.PRNGKey(seed + 1), y)
    ratio = float(jnp.sum(Sy * Sy) / jnp.sum(y * y))
    assert 0.5 < ratio < 2.0, (kind, ratio)


@FAST
@given(q=st.integers(1, 16), d=st.integers(1, 8), seed=st.integers(0, 2**20))
def test_masked_average_permutation_invariant(q, d, seed):
    key = jax.random.PRNGKey(seed)
    xs = jax.random.normal(key, (q, d))
    mask = (jax.random.uniform(jax.random.fold_in(key, 1), (q,)) > 0.4).astype(jnp.float32)
    perm = jax.random.permutation(jax.random.fold_in(key, 2), q)
    a = averaging.masked_average(xs, mask)
    b = averaging.masked_average(xs[perm], mask[perm])
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)


@FAST
@given(
    shapes=st.lists(st.tuples(st.integers(1, 5), st.integers(1, 5)), min_size=1, max_size=4),
    seed=st.integers(0, 2**20),
)
def test_tree_vectorizer_roundtrip(shapes, seed):
    key = jax.random.PRNGKey(seed)
    tree = {f"l{i}": jax.random.normal(jax.random.fold_in(key, i), s) for i, s in enumerate(shapes)}
    vec, vz = tu.tree_flatten_to_vector(tree)
    back = vz.unflatten(vec)
    for a, b in zip(jax.tree_util.tree_leaves(back), jax.tree_util.tree_leaves(tree)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


@FAST
@given(k=st.sampled_from([1, 2, 4, 8, 64, 128]))
def test_hadamard_orthogonality(k):
    H = np.asarray(kcommon.hadamard_matrix(k))
    np.testing.assert_array_equal(H @ H.T, k * np.eye(k))


@FAST
@given(
    s=st.integers(2, 64),
    hd=st.sampled_from([4, 8, 16]),
    frac=st.sampled_from([0.5, 1.0]),
    seed=st.integers(0, 2**20),
)
def test_rope_norm_preservation(s, hd, frac, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, s, 2, hd))
    cos, sin = layers.rope_angles(jnp.arange(s), int(hd * frac) & ~1, 1e4)
    y = layers.apply_rope(x, cos[None], sin[None], frac)
    np.testing.assert_allclose(
        np.asarray(jnp.linalg.norm(y, axis=-1)),
        np.asarray(jnp.linalg.norm(x, axis=-1)),
        rtol=1e-4,
    )


@FAST
@given(m=st.integers(1, 10**6), n=st.integers(1, 10**9))
def test_privacy_bound_monotone(m, n):
    v = privacy.mi_per_entry_bound(m, n)
    assert v >= 0
    assert privacy.mi_per_entry_bound(m + 1, n) >= v
    assert privacy.mi_per_entry_bound(m, n + 1) <= v or n > 10**8  # fp slack at huge n


@FAST
@given(pos=st.integers(0, 10_000), s_cache=st.sampled_from([4, 16, 64, 512]))
def test_ring_slot_invariants(pos, s_cache):
    """Ring-cache math: the slot being written always maps back to `pos`, and every
    valid slot holds a position in (pos - s_cache, pos]."""
    from repro.models.lm import _ring_update_and_scores_mask

    slot, valid = _ring_update_and_scores_mask(jnp.int32(pos), s_cache)
    idx = np.arange(s_cache)
    ages = np.mod(pos - idx, s_cache)
    k_pos = pos - ages
    assert int(slot) == pos % s_cache
    assert k_pos[int(slot)] == pos
    v = np.asarray(valid)
    assert (k_pos[v] > pos - s_cache).all() and (k_pos[v] <= pos).all()
    assert (k_pos[~v] < 0).all()


@FAST
@given(
    lats=st.lists(
        st.sampled_from([0.05, 0.1, 0.3, 0.5, 1.0, 2.0, 5.0]), min_size=0, max_size=32
    ),
    scale=st.sampled_from([1.0, 1.5, 3.0, 10.0]),
)
def test_adaptive_deadline_monotone_and_clamped(lats, scale):
    """The adaptive deadline is monotone in the observed latencies (scaling every
    sample up can only raise it) and always inside [min_s, max_s]; before
    min_samples observations the clamped warm-up default applies."""
    from repro.runtime.engine import AdaptiveDeadline

    pol = AdaptiveDeadline(warmup_s=1.0, min_samples=5, window=64, min_s=0.2, max_s=4.0)
    tr, tr_scaled = pol.start(), pol.start()
    for v in lats:
        tr.observe(v)
        tr_scaled.observe(v * scale)
    d, d_scaled = tr.current(), tr_scaled.current()
    assert pol.min_s <= d <= pol.max_s
    assert pol.min_s <= d_scaled <= pol.max_s
    if len(lats) < pol.min_samples:
        assert d == d_scaled == min(max(pol.warmup_s, pol.min_s), pol.max_s)
    else:
        assert d_scaled >= d - 1e-12


@FAST
@given(k=st.integers(0, 12), start=st.sampled_from([0.1, 0.5, 2.0]))
def test_adaptive_deadline_timeout_escalation(k, start):
    """Censored observations (timeouts) never shrink the deadline: feeding back
    each current deadline as a timeout produces a non-decreasing sequence."""
    from repro.runtime.engine import AdaptiveDeadline

    pol = AdaptiveDeadline(
        warmup_s=start, min_samples=1, margin=1.0, timeout_factor=1.5, max_s=50.0
    )
    tr = pol.start()
    prev = tr.current()
    for _ in range(k):
        tr.observe_timeout(prev)
        cur = tr.current()
        assert cur >= prev - 1e-12
        prev = cur


@FAST
@given(vocab=st.integers(1, 300_000))
def test_padded_vocab_properties(vocab):
    import dataclasses

    from repro.configs.base import get_config

    cfg = dataclasses.replace(get_config("granite-3-8b"), vocab_size=vocab)
    pv = cfg.padded_vocab
    assert pv >= vocab and pv % 256 == 0 and pv - vocab < 256
