"""Per-kernel allclose sweeps: Pallas (interpret=True) vs pure-jnp oracle."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import common
from repro.kernels.fwht import ops as fwht_ops, ref as fwht_ref
from repro.kernels.sjlt import ops as sjlt_ops, ref as sjlt_ref
from repro.kernels.gaussian import ops as g_ops, ref as g_ref


# ------------------------------------------------------------------ common


def test_hadamard_matrix_orthogonal():
    for k in (1, 2, 4, 64, 128):
        H = np.asarray(common.hadamard_matrix(k))
        np.testing.assert_allclose(H @ H.T, k * np.eye(k), atol=0)


def test_threefry_is_deterministic_and_uniformish():
    c0 = jnp.arange(1 << 14, dtype=jnp.uint32)
    c1 = jnp.zeros_like(c0)
    a0, a1 = common.threefry2x32(jnp.uint32(1), jnp.uint32(2), c0, c1)
    b0, _ = common.threefry2x32(jnp.uint32(1), jnp.uint32(2), c0, c1)
    assert jnp.array_equal(a0, b0)
    u = common.bits_to_open_unit(a0)
    assert 0.45 < float(u.mean()) < 0.55
    assert float(u.min()) > 0.0 and float(u.max()) < 1.0
    # different key → different stream
    d0, _ = common.threefry2x32(jnp.uint32(1), jnp.uint32(3), c0, c1)
    assert not jnp.array_equal(a0, d0)


def test_counter_normal_moments():
    c0 = jnp.arange(1 << 15, dtype=jnp.uint32)
    z = common.counter_normal(jnp.uint32(5), jnp.uint32(9), c0, c0 * jnp.uint32(7919))
    assert abs(float(z.mean())) < 0.02
    assert abs(float(z.std()) - 1.0) < 0.02


# ------------------------------------------------------------------ fwht


@pytest.mark.parametrize("n", [2, 8, 128, 256, 1024, 8192])
@pytest.mark.parametrize("d", [1, 7, 128, 300])
def test_fwht_matches_ref(n, d):
    x = jax.random.normal(jax.random.PRNGKey(n * 1000 + d), (n, d), dtype=jnp.float32)
    got = fwht_ops.fwht(x)
    want = fwht_ref.fwht(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fwht_dtypes(dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), (512, 128)).astype(dtype)
    got = fwht_ops.fwht(x)
    assert got.dtype == dtype
    want = fwht_ref.fwht(x.astype(jnp.float32))
    tol = 1e-5 if dtype == jnp.float32 else 0.15
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want), rtol=tol, atol=tol * 512
    )


def test_fwht_multipass_kronecker():
    """n large enough to trigger the two-pass (cross-tile) path."""
    n = 2 * fwht_ops.MAX_TILE_ROWS
    x = jax.random.normal(jax.random.PRNGKey(3), (n, 4), dtype=jnp.float32)
    got = fwht_ops.fwht(x)
    want = fwht_ref.fwht(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-2)


def test_fwht_is_involution_up_to_n():
    n, d = 256, 16
    x = jax.random.normal(jax.random.PRNGKey(1), (n, d))
    twice = fwht_ops.fwht(fwht_ops.fwht(x))
    np.testing.assert_allclose(np.asarray(twice), n * np.asarray(x), rtol=1e-4, atol=1e-2)


def test_fwht_vector_input():
    x = jax.random.normal(jax.random.PRNGKey(2), (64,))
    got = fwht_ops.fwht(x)
    want = fwht_ref.fwht(x[:, None])[:, 0]
    assert got.shape == (64,)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)


# ------------------------------------------------------------------ sjlt


@pytest.mark.parametrize("n,d,m,s", [
    (100, 7, 32, 1),
    (256, 128, 64, 4),
    (1000, 33, 200, 2),
    (4096, 256, 512, 8),
    (777, 130, 1000, 4),   # m > BLOCK_M boundary-ish and unaligned everything
])
def test_sjlt_matches_ref(n, d, m, s):
    key = jax.random.PRNGKey(n + d + m + s)
    A = jax.random.normal(jax.random.fold_in(key, 1), (n, d), dtype=jnp.float32)
    buckets, signs = sjlt_ops.sjlt_params(key, n, s, m)
    got = sjlt_ops.sjlt_apply(A, buckets, signs, m)
    want = sjlt_ref.sjlt_apply(A, buckets, signs, m)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)


def test_sjlt_kernel_path_equals_core_path():
    """core.sketches sjlt (segment_sum) and the kernel draw the same S per key."""
    from repro.core import sketches as sk

    key = jax.random.PRNGKey(42)
    A = jax.random.normal(jax.random.PRNGKey(1), (300, 40))
    a = sk.sjlt_sketch(key, A, 64, s=4, use_kernel=False)
    b = sjlt_ops.sjlt_sketch(key, A, 64, s=4)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-4)


def test_sjlt_embedding_property():
    """E[SᵀS]=I: norms preserved in expectation."""
    n, d, m, s = 512, 8, 256, 4
    A = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    norms = []
    for t in range(20):
        SA = sjlt_ops.sjlt_sketch(jax.random.PRNGKey(t), A, m, s=s)
        norms.append(float(jnp.linalg.norm(SA) ** 2))
    true = float(jnp.linalg.norm(A) ** 2)
    assert abs(np.mean(norms) / true - 1.0) < 0.1


# ------------------------------------------------------------------ gaussian


@pytest.mark.parametrize("n,d,m", [
    (64, 8, 16),
    (300, 130, 100),
    (1024, 256, 512),
    (513, 1, 300),
])
def test_gaussian_kernel_matches_ref(n, d, m):
    key = jax.random.PRNGKey(n * 7 + d * 3 + m)
    A = jax.random.normal(jax.random.fold_in(key, 1), (n, d), dtype=jnp.float32)
    got = g_ops.gaussian_sketch(key, A, m)
    want = g_ref.gaussian_sketch(key, A, m)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_gaussian_kernel_statistics():
    """Entries of the implied S are N(0, 1/m): check via S = sketch of I."""
    n, m = 256, 128
    S = g_ops.gaussian_sketch(jax.random.PRNGKey(9), jnp.eye(n), m)
    z = np.asarray(S).ravel() * math.sqrt(m)
    assert abs(z.mean()) < 0.02
    assert abs(z.std() - 1.0) < 0.02
    # normality sanity: 4th moment ≈ 3
    assert abs((z**4).mean() - 3.0) < 0.3


def test_gaussian_kernel_grid_order_invariance():
    """Counter-based RNG ⇒ the same (key, i, j) element regardless of blocking."""
    key = jax.random.PRNGKey(3)
    A = jax.random.normal(jax.random.PRNGKey(4), (700, 60))
    full = g_ref.sketch_matrix(key, 96, 700)
    got = g_ops.gaussian_sketch(key, A, 96)
    want = full @ np.asarray(A)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)


def test_gaussian_kernel_unbiased_solver_error():
    """End-to-end: kernel-sketched solve obeys Lemma 1 like the jnp path."""
    from repro.core import solve, theory

    n, d, m = 2048, 10, 64
    A = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    b = A @ jnp.ones((d,)) + jax.random.normal(jax.random.PRNGKey(1), (n,))
    xstar = solve.lstsq(A, b)
    fstar = float(solve.residual_cost(A, b, xstar))
    errs = []
    for t in range(60):
        SA = g_ops.gaussian_sketch(jax.random.PRNGKey(t), jnp.concatenate([A, b[:, None]], 1), m)
        x = solve.lstsq(SA[:, :-1], SA[:, -1])
        errs.append(float(solve.relative_error(A, b, x, fstar)))
    pred = theory.gaussian_single_error(m, d)
    assert 0.6 < np.mean(errs) / pred < 1.6
