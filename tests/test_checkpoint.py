"""Checkpoint store: roundtrip, bf16, atomicity, async overlap, GC, elasticity."""
import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint, save_checkpoint


def _tree(key):
    return {
        "params": {
            "w": jax.random.normal(key, (8, 4)),
            "emb": (jax.random.normal(key, (16, 4)) * 0.1).astype(jnp.bfloat16),
        },
        "step": jnp.int32(7),
    }


def test_roundtrip_including_bf16(tmp_path):
    t = _tree(jax.random.PRNGKey(0))
    save_checkpoint(str(tmp_path), 3, t)
    like = jax.eval_shape(lambda: t)
    r = restore_checkpoint(str(tmp_path), 3, like)
    for a, b in zip(jax.tree_util.tree_leaves(r), jax.tree_util.tree_leaves(t)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_latest_step_ignores_tmp(tmp_path):
    t = _tree(jax.random.PRNGKey(0))
    save_checkpoint(str(tmp_path), 1, t)
    save_checkpoint(str(tmp_path), 5, t)
    os.makedirs(tmp_path / "step_00000009.tmp")
    assert latest_step(str(tmp_path)) == 5
    assert latest_step(str(tmp_path / "missing")) is None


def test_restore_validates_shapes(tmp_path):
    t = _tree(jax.random.PRNGKey(0))
    save_checkpoint(str(tmp_path), 1, t)
    bad = jax.eval_shape(lambda: {**t, "params": {**t["params"], "w": jnp.zeros((9, 4))}})
    with pytest.raises(ValueError, match="shape mismatch"):
        restore_checkpoint(str(tmp_path), 1, bad)


def test_restore_missing_leaf(tmp_path):
    t = _tree(jax.random.PRNGKey(0))
    save_checkpoint(str(tmp_path), 1, t)
    bigger = jax.eval_shape(lambda: {**t, "extra": jnp.zeros((2,))})
    with pytest.raises(KeyError):
        restore_checkpoint(str(tmp_path), 1, bigger)


def test_async_checkpointer_and_gc(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    t = _tree(jax.random.PRNGKey(0))
    for s in (1, 2, 3, 4):
        ck.save(s, t)
    ck.wait()
    steps = sorted(
        int(n.split("_")[1]) for n in os.listdir(tmp_path) if n.startswith("step_") and "." not in n
    )
    assert steps == [3, 4]


def test_async_snapshot_isolated_from_mutation(tmp_path):
    """The snapshot must capture values at save() time even if buffers change."""
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    t = {"w": jnp.ones((4,))}
    ck.save(1, t)
    ck.wait()
    r = restore_checkpoint(str(tmp_path), 1, jax.eval_shape(lambda: t))
    np.testing.assert_array_equal(np.asarray(r["w"]), np.ones((4,)))


def test_elastic_restore_onto_mesh(tmp_path):
    """Restore with explicit shardings (any-mesh restart)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    t = {"w": jnp.arange(16.0).reshape(4, 4)}
    save_checkpoint(str(tmp_path), 2, t)
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    r = restore_checkpoint(str(tmp_path), 2, jax.eval_shape(lambda: t), shardings=sh)
    np.testing.assert_array_equal(np.asarray(r["w"]), np.asarray(t["w"]))
    assert r["w"].sharding == sh["w"]
