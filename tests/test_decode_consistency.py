"""Cache-semantics integration test: forward == batched prefill == token-by-token
prefill == decode continuation, for every assigned architecture (reduced configs).

This is the test that catches ring-buffer indexing, RoPE absolute-position, SSM
recurrence, MLA latent-absorption and local:global grouping bugs (it caught the
reversed depthwise-conv taps and the VLM patch-merge omission during development).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED
from repro.configs.base import get_config
from repro.models import lm

TOL = dict(rtol=2e-2, atol=2e-2)


def _cfg(arch):
    cfg = get_config(arch).reduced()
    if cfg.moe:  # dropless capacity: decode groups over batch, prefill over sequence
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.num_experts))
    return cfg


def _batch(cfg, key, B, S):
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "labels": jnp.zeros((B, S), jnp.int32),
        "loss_mask": jnp.ones((B, S), jnp.float32),
    }
    if cfg.vlm:
        batch["patches"] = jax.random.normal(key, (B, 4, cfg.vit_dim), jnp.float32)
    if cfg.encdec:
        batch["frames"] = jax.random.normal(key, (B, cfg.enc_seq, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_prefill_decode_equals_forward(arch):
    cfg = _cfg(arch)
    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key)
    B, S = 2, 24
    batch = _batch(cfg, key, B, S)

    full = lm.forward_logits(params, cfg, batch)
    logits_bp, cache_bp = jax.jit(
        lambda p, b: lm.batched_prefill(p, cfg, b, cache_len=S + 4)
    )(params, batch)
    cache0 = lm.init_cache(cfg, B, S + 4)
    logits_tt, cache_tt = jax.jit(lambda p, b, c: lm.prefill(p, cfg, b, c))(params, batch, cache0)

    np.testing.assert_allclose(np.asarray(logits_bp), np.asarray(full[:, -1]), **TOL)
    np.testing.assert_allclose(np.asarray(logits_tt), np.asarray(full[:, -1]), **TOL)

    # decode continuation from both caches must agree (same greedy next step)
    tok = jnp.argmax(logits_bp, -1).astype(jnp.int32)
    dec = jax.jit(lambda p, t, c: lm.decode_step(p, cfg, t, c, jnp.int32(S)))
    l1, _ = dec(params, tok, cache_bp)
    l2, _ = dec(params, tok, cache_tt)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), **TOL)
    assert np.isfinite(np.asarray(l1)).all()


def test_swa_ring_cache_bounded():
    """SWA cache allocation is window-bounded, not context-bounded."""
    cfg = _cfg("mixtral-8x7b")
    cache = lm.init_cache(cfg, 2, 1000)
    assert cache["k"].shape[2] == min(cfg.window, 1000) == cfg.window


def test_gemma_cache_split_sizes():
    cfg = _cfg("gemma3-12b")
    cache = lm.init_cache(cfg, 2, 2000)
    n_local = cfg.num_layers // (cfg.local_global_ratio + 1) * cfg.local_global_ratio
    assert cache["local"]["k"].shape[0] == n_local
    assert cache["local"]["k"].shape[2] == cfg.window
    assert cache["global"]["k"].shape[2] == 2000


def test_ssm_cache_constant_memory():
    cfg = _cfg("falcon-mamba-7b")
    c1 = lm.init_cache(cfg, 2, 100)
    c2 = lm.init_cache(cfg, 2, 100_000)
    assert all(
        a.shape == b.shape
        for a, b in zip(jax.tree_util.tree_leaves(c1), jax.tree_util.tree_leaves(c2))
    )
