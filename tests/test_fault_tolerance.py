"""Fault tolerance: straggler policies, heartbeat stats, solver head-fit quality."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import privacy, sketches as sk
from repro.distributed.fault_tolerance import HeartbeatMonitor, StragglerPolicy
from repro.train import solvers


def test_straggler_policy_deterministic_per_step():
    pol = StragglerPolicy(drop_prob=0.3, seed=42)
    a = pol.mask_for_step(5, 64)
    b = pol.mask_for_step(5, 64)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    c = pol.mask_for_step(6, 64)
    assert not np.array_equal(np.asarray(a), np.asarray(c))


def test_heartbeat_monitor_report():
    mon = HeartbeatMonitor(q=8, deadline=1.0)
    rt = np.array([0.5, 0.6, 0.7, 0.8, 0.9, 1.1, 1.5, 0.4])
    mask = mon.record_step(rt)
    assert mask.sum() == 6
    rep = mon.report()
    assert rep["on_time_fraction"] == 6 / 8
    assert rep["effective_q"] == 6.0
    assert rep["p95_runtime"] >= rep["mean_runtime"]


def test_heartbeat_report_p50_and_retry_timeout_counts():
    mon = HeartbeatMonitor(q=4, deadline=1.0)
    mon.record_step(np.array([0.2, 0.4, 0.6, 1.4]))
    mon.record_step(np.array([0.3, 0.5, np.inf, 0.9]))  # a hard drop
    mon.record_timeout(2)
    mon.record_retry()
    rep = mon.report()
    assert rep["timeouts"] == 2.0 and rep["retries"] == 1.0
    assert rep["p50_runtime"] <= rep["p95_runtime"]
    assert np.isfinite(rep["mean_runtime"])  # inf arrivals excluded from moments
    assert rep["on_time_fraction"] == 6 / 8


def test_straggler_policy_to_latency_model():
    pol = StragglerPolicy(drop_prob=0.3, deadline_quantile=0.8, seed=11)
    model = pol.to_latency_model(mean_s=1.0, sigma=0.4)
    wave = model.sample_wave(1024)
    np.testing.assert_array_equal(wave, model.sample_wave(1024))  # seeded
    assert 0.2 < np.isinf(wave).mean() < 0.4  # drop_prob carried over
    # the derived deadline keeps ~deadline_quantile of the *surviving* lognormals
    cut = pol.deadline_for(mean_s=1.0, sigma=0.4)
    finite = wave[np.isfinite(wave)]
    assert abs((finite <= cut).mean() - 0.8) < 0.05
    assert StragglerPolicy(deadline_quantile=1.0).deadline_for() == float("inf")


def test_runtime_telemetry_subsumes_heartbeat_report():
    """An engine run's summary embeds the (extended) HeartbeatMonitor schema."""
    import jax.numpy as jnp

    from repro import runtime as rt

    key = jax.random.PRNGKey(0)
    A = jax.random.normal(key, (512, 8))
    b = jax.random.normal(jax.random.PRNGKey(1), (512,))
    spec = sk.SketchSpec("gaussian", 64)
    res = rt.serverless_sketch_solve(
        spec, key, A, b, q=8,
        latency=rt.LognormalLatency(seed=4, mean_s=0.5, sigma=0.6),
        config=rt.RuntimeConfig(deadline_s=0.55, max_retries=2),
    )
    s = res.summary(deadline=0.55)
    hb = s["heartbeat"]
    legacy_keys = {"steps", "mean_runtime", "p95_runtime", "on_time_fraction", "effective_q"}
    assert legacy_keys <= set(hb)  # strict superset of the old schema
    assert {"p50_runtime", "timeouts", "retries"} <= set(hb)
    assert hb["timeouts"] == s["timeouts"] and hb["retries"] == s["retries"]
    # attempt-0 on-time fraction in the monitor == the engine's realized first wave
    assert hb["on_time_fraction"] * 8 == float((np.asarray(res.realized_mask) > 0).sum())


def test_fit_head_converges_to_exact():
    key = jax.random.PRNGKey(0)
    n, d, k = 4096, 16, 3
    H = jax.random.normal(key, (n, d))
    W_true = jax.random.normal(jax.random.PRNGKey(1), (d, k))
    Y = H @ W_true + 0.1 * jax.random.normal(jax.random.PRNGKey(2), (n, k))
    spec = sk.SketchSpec("gaussian", 8 * d)
    acc = privacy.PrivacyAccountant()
    W = solvers.fit_head(key, H, Y, spec, q=16, accountant=acc)
    quality = solvers.head_fit_quality(H, Y, W)
    assert quality["rel_err"] < 0.05, quality
    assert len(acc.disclosures) == 16


def test_fit_head_straggler_mask():
    key = jax.random.PRNGKey(0)
    n, d = 1024, 8
    H = jax.random.normal(key, (n, d))
    # noisy target: f* must be bounded away from 0 or rel_err is ill-conditioned
    y = H @ jax.random.normal(jax.random.PRNGKey(1), (d,)) + jax.random.normal(
        jax.random.PRNGKey(2), (n,)
    )
    spec = sk.SketchSpec("gaussian", 8 * d)
    mask = jnp.array([1.0] * 4 + [0.0] * 4)
    W = solvers.fit_head(key, H, y, spec, q=8, straggler_mask=mask)
    assert np.isfinite(np.asarray(W)).all()
    q = solvers.head_fit_quality(H, y, W)
    assert q["rel_err"] < 0.2
