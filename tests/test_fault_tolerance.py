"""Fault tolerance: straggler policies, heartbeat stats, solver head-fit quality."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import privacy, sketches as sk
from repro.distributed.fault_tolerance import HeartbeatMonitor, StragglerPolicy
from repro.train import solvers


def test_straggler_policy_deterministic_per_step():
    pol = StragglerPolicy(drop_prob=0.3, seed=42)
    a = pol.mask_for_step(5, 64)
    b = pol.mask_for_step(5, 64)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    c = pol.mask_for_step(6, 64)
    assert not np.array_equal(np.asarray(a), np.asarray(c))


def test_heartbeat_monitor_report():
    mon = HeartbeatMonitor(q=8, deadline=1.0)
    rt = np.array([0.5, 0.6, 0.7, 0.8, 0.9, 1.1, 1.5, 0.4])
    mask = mon.record_step(rt)
    assert mask.sum() == 6
    rep = mon.report()
    assert rep["on_time_fraction"] == 6 / 8
    assert rep["effective_q"] == 6.0
    assert rep["p95_runtime"] >= rep["mean_runtime"]


def test_fit_head_converges_to_exact():
    key = jax.random.PRNGKey(0)
    n, d, k = 4096, 16, 3
    H = jax.random.normal(key, (n, d))
    W_true = jax.random.normal(jax.random.PRNGKey(1), (d, k))
    Y = H @ W_true + 0.1 * jax.random.normal(jax.random.PRNGKey(2), (n, k))
    spec = sk.SketchSpec("gaussian", 8 * d)
    acc = privacy.PrivacyAccountant()
    W = solvers.fit_head(key, H, Y, spec, q=16, accountant=acc)
    quality = solvers.head_fit_quality(H, Y, W)
    assert quality["rel_err"] < 0.05, quality
    assert len(acc.disclosures) == 16


def test_fit_head_straggler_mask():
    key = jax.random.PRNGKey(0)
    n, d = 1024, 8
    H = jax.random.normal(key, (n, d))
    # noisy target: f* must be bounded away from 0 or rel_err is ill-conditioned
    y = H @ jax.random.normal(jax.random.PRNGKey(1), (d,)) + jax.random.normal(
        jax.random.PRNGKey(2), (n,)
    )
    spec = sk.SketchSpec("gaussian", 8 * d)
    mask = jnp.array([1.0] * 4 + [0.0] * 4)
    W = solvers.fit_head(key, H, y, spec, q=8, straggler_mask=mask)
    assert np.isfinite(np.asarray(W)).all()
    q = solvers.head_fit_quality(H, y, W)
    assert q["rel_err"] < 0.2
