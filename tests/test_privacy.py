"""Eq. (5) privacy accounting."""
import math

import pytest

from repro.core import privacy


def test_paper_airline_number():
    v = privacy.mi_per_entry_bound(int(5e5), int(1.21e8), gamma=1.0)
    assert abs(v - 1.17e-2) < 2e-4  # the paper's §VI-A evaluation


def test_bound_scales_linearly_in_m():
    a = privacy.mi_per_entry_bound(100, 10_000)
    b = privacy.mi_per_entry_bound(200, 10_000)
    assert abs(b - 2 * a) < 1e-12


def test_bound_vanishes_as_n_grows():
    vals = [privacy.mi_per_entry_bound(64, n) for n in (10**3, 10**5, 10**7)]
    assert vals[0] > vals[1] > vals[2]
    assert vals[2] < 1e-4


def test_sketch_dim_inversion_consistent():
    n = 10**6
    m = privacy.sketch_dim_for_privacy(n, 0.01)
    assert privacy.mi_per_entry_bound(m, n) <= 0.0100001
    assert privacy.mi_per_entry_bound(m + 2, n) > 0.01


def test_accountant_composition():
    acc = privacy.PrivacyAccountant()
    for _ in range(10):
        acc.record(100, 10_000)
    single = privacy.mi_per_entry_bound(100, 10_000)
    assert abs(acc.total_per_entry_nats - 10 * single) < 1e-12
    assert "TOTAL" in acc.report()


def test_invalid_inputs():
    with pytest.raises(ValueError):
        privacy.mi_per_entry_bound(0, 10)
