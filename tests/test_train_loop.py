"""Trainer integration: loss decreases, crash-recovery restart-determinism."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig, get_config
from repro.optim import AdamWConfig
from repro.train import Trainer, TrainerConfig


def _tiny_cfg():
    # 2-layer dense decoder, small vocab — fast on CPU
    return dataclasses.replace(
        get_config("granite-3-8b").reduced(), num_layers=2, d_model=32, d_ff=64,
        num_heads=2, num_kv_heads=1, head_dim=16, vocab_size=97,
    )


def test_loss_decreases():
    cfg = _tiny_cfg()
    tc = TrainerConfig(batch=8, seq=64, log_every=5)
    tr = Trainer(cfg, AdamWConfig(lr=3e-3), tc)
    tr.run(40)
    first = tr.history[0]["loss"]
    last = tr.history[-1]["loss"]
    assert last < first - 0.3, (first, last)


def test_restart_determinism(tmp_path):
    """checkpoint @5 → crash @7 → recover == uninterrupted run (bitwise)."""
    cfg = _tiny_cfg()
    opt = AdamWConfig(lr=1e-3)

    tc_a = TrainerConfig(batch=4, seq=32, ckpt_dir=str(tmp_path / "a"), ckpt_every=5)
    ref = Trainer(cfg, opt, tc_a).run(10)

    tc_b = TrainerConfig(
        batch=4, seq=32, ckpt_dir=str(tmp_path / "b"), ckpt_every=5, fail_at_step=7
    )
    rec = Trainer(cfg, opt, tc_b).run(10)

    for a, b in zip(jax.tree_util.tree_leaves(ref["params"]), jax.tree_util.tree_leaves(rec["params"])):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
    assert int(rec["step"]) == 10


def test_resume_from_checkpoint_continues(tmp_path):
    cfg = _tiny_cfg()
    opt = AdamWConfig(lr=1e-3)
    tc = TrainerConfig(batch=4, seq=32, ckpt_dir=str(tmp_path), ckpt_every=5)
    Trainer(cfg, opt, tc).run(5)
    tr2 = Trainer(cfg, opt, tc)
    state = tr2.init_or_restore()
    assert int(state["step"]) == 5
    final = tr2.run(8, state=state)
    assert int(final["step"]) == 8


def test_grad_accumulation_equivalence():
    """accum=2 over batch 8 == accum=1 over the same batch (same grads → same params)."""
    from repro.train.state import init_train_state
    from repro.train.step import make_train_step
    from repro.data import lm_batch

    cfg = _tiny_cfg()
    opt = AdamWConfig(lr=1e-3, grad_clip=0.0)
    state0 = init_train_state(cfg, opt, jax.random.PRNGKey(0))
    batch = lm_batch(0, 0, batch=8, seq=32, vocab=cfg.vocab_size)
    s1, m1 = jax.jit(make_train_step(cfg, opt, accum_steps=1))(state0, batch)
    state0b = init_train_state(cfg, opt, jax.random.PRNGKey(0))
    s2, m2 = jax.jit(make_train_step(cfg, opt, accum_steps=2))(state0b, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(s1["params"]), jax.tree_util.tree_leaves(s2["params"])):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=1e-4, atol=1e-6
        )
