"""benchmarks.run CLI: unknown keys fail loudly, listing what is registered."""
from __future__ import annotations

import pytest


@pytest.fixture(scope="module")
def bench_run():
    return pytest.importorskip("benchmarks.run")


def test_unknown_key_lists_registered_keys(bench_run, capsys):
    rc = bench_run.main(["--only", "bogus,fused"])
    err = capsys.readouterr().err
    assert rc == 2
    assert "unknown benchmark key(s) bogus" in err
    # the full registry is echoed so the caller can pick a valid key
    for key in sorted(bench_run.MODULES):
        assert key in err


def test_known_keys_pass_validation(bench_run):
    unknown = [k for k in ["fused", "thm1"] if k not in bench_run.MODULES]
    assert unknown == []
