"""Roofline helpers: HLO collective parsing, wire-byte model, extrapolation."""
import numpy as np

from repro.roofline import collectives as C
from repro.roofline.hw import V5E
from repro.roofline.model import model_flops_for, roofline_terms

HLO = """
ENTRY %main {
  %ag = f32[4096,512]{1,0} all-gather(%x), channel_id=1, replica_groups={{0,1,2,3}}, dimensions={1}
  %ar = bf16[1024]{0} all-reduce(%y), channel_id=2, replica_groups=[32,16]<=[512], use_global_device_ids=true
  %rs = f32[256,128]{1,0} reduce-scatter(%z), channel_id=3, replica_groups={{0,256}}, dimensions={0}
  %cp = f32[64]{0} collective-permute(%w), channel_id=4, source_target_pairs={{0,256},{256,0}}
  %a2a = (f32[16,16]{1,0}, f32[16,16]{1,0}) all-to-all(%p, %q), replica_groups={{0,1}}
  %dot = f32[128,128]{1,0} dot(%a, %b)
}
"""


def test_parse_collectives_kinds_and_bytes():
    ops = C.parse_collectives(HLO)
    kinds = sorted(o.kind for o in ops)
    assert kinds == ["all-gather", "all-reduce", "all-to-all", "collective-permute", "reduce-scatter"]
    by = {o.kind: o for o in ops}
    assert by["all-gather"].bytes == 4096 * 512 * 4
    assert by["all-gather"].group_size == 4
    assert by["all-reduce"].bytes == 1024 * 2
    assert by["all-reduce"].group_size == 16  # iota [32,16]
    assert by["all-to-all"].bytes == 2 * 16 * 16 * 4  # tuple shapes summed


def test_pod_crossing_detection():
    ops = C.parse_collectives(HLO, pod_size=256)
    by = {o.kind: o for o in ops}
    assert by["reduce-scatter"].crosses_pod  # group {0,256}
    assert by["collective-permute"].crosses_pod  # pair (0,256)
    assert not by["all-gather"].crosses_pod  # group {0..3}


def test_wire_bytes_model():
    ops = C.parse_collectives(HLO)
    by = {o.kind: o for o in ops}
    # all-reduce: 2*(P-1)/P * bytes
    np.testing.assert_allclose(C.op_wire_bytes(by["all-reduce"]), 2 * 15 / 16 * 2048)
    # all-gather: (P-1)/P * bytes
    np.testing.assert_allclose(C.op_wire_bytes(by["all-gather"]), 3 / 4 * 4096 * 512 * 4)
    # permute: raw bytes
    np.testing.assert_allclose(C.op_wire_bytes(by["collective-permute"]), 64 * 4)


def test_collective_seconds_dcn_split():
    ops = C.parse_collectives(HLO, pod_size=256)
    res = C.collective_seconds(ops, ici_bw=V5E.ici_link_bw, dcn_bw=V5E.dcn_bw)
    assert res["total_s"] > 0 and res["dcn_s"] > 0
    assert res["dcn_s"] <= res["total_s"]


def test_roofline_terms_bottleneck():
    rr = roofline_terms(
        arch="x", shape="train_4k", mesh="pod16x16", chips=256,
        cost={"flops": 1e12, "bytes accessed": 1e9},
        hlo_text=HLO, model_flops=1e14,
    )
    assert rr.compute_s == 1e12 / V5E.peak_flops_bf16
    assert rr.memory_s == 1e9 / V5E.hbm_bw
    assert rr.bottleneck in ("compute", "memory", "collective")
    assert 0 < rr.roofline_fraction <= 1.0


def test_model_flops_modes():
    from repro.configs.base import SHAPES, get_config

    cfg = get_config("mixtral-8x7b")
    t = model_flops_for(cfg, SHAPES["train_4k"], mode="train")
    p = model_flops_for(cfg, SHAPES["prefill_32k"], mode="prefill")
    d = model_flops_for(cfg, SHAPES["decode_32k"], mode="decode")
    assert t > p > d > 0
    # MoE: active < total params
    assert cfg.active_param_count() < cfg.param_count()
    dense = get_config("granite-3-8b")
    assert dense.active_param_count() == dense.param_count()


def test_extrapolation_math():
    from repro.roofline import model as dr

    assert dr.extrapolate(10.0, 20.0, 1, 2, 40) == 10.0 + 10.0 * 39
    cost, agg = dr.extrapolate_cell(
        {"flops": 100.0}, {"flops": 150.0},
        {"all-reduce": {"count": 2, "bytes": 10.0, "wire_bytes": 10.0, "dcn_wire_bytes": 0.0}},
        {"all-reduce": {"count": 3, "bytes": 15.0, "wire_bytes": 15.0, "dcn_wire_bytes": 0.0}},
        1, 2, 10,
    )
    assert cost["flops"] == 100.0 + 50.0 * 9
    assert agg["all-reduce"]["count"] == 2 + 1 * 9
