"""Fused single-pass sketch→Gram pipeline: oracle equivalence and batching paths.

The fused path never materializes SA — every test here checks it against the
two-pass reference (materialize S, form (SA)ᵀ(SA) densely) or against the
loop fallback under shared worker keys.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import operators as ops, sketches as sk, solve
from repro.utils import prng

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

N, D, M = 100, 7, 24  # N not a power of two / multiple of the block sizes below


def _op(kind, key, n=N, m=M, use_kernel=False):
    if kind == "hybrid":
        spec = sk.SketchSpec("hybrid", m, m_prime=min(2 * m, n), inner="sjlt", s=2)
    elif kind == "sjlt":
        spec = sk.SketchSpec(kind, m, s=3, use_kernel=use_kernel)
    elif kind == "uniform":
        spec = sk.SketchSpec(kind, m, replacement=False)
    else:
        spec = sk.SketchSpec(kind, m, use_kernel=use_kernel)
    scores = None
    if kind == "leverage":
        A = jax.random.normal(jax.random.PRNGKey(7), (n, 5))
        scores = sk.leverage_scores(A)
    return ops.make_operator(spec, key, n, scores=scores)


def _oracle(op, A, b):
    """Two-pass reference: explicit S, dense SA, dense Gram."""
    S = np.asarray(op.materialize(), np.float64)
    SA = S @ np.asarray(A, np.float64)
    Sb = S @ np.asarray(b, np.float64)
    return SA.T @ SA, SA.T @ Sb


@pytest.mark.parametrize("kind", sk.KINDS)
@pytest.mark.parametrize("block_rows", [33, 96])
def test_gram_blocked_matches_materialized_oracle(kind, block_rows):
    """(G, c) from the fused streamed pass == (SA)ᵀ(SA), (SA)ᵀ(Sb) for every
    registered kind and block sizes that do not divide n."""
    op = _op(kind, jax.random.PRNGKey(3))
    A = jax.random.normal(jax.random.PRNGKey(0), (N, D))
    b = jax.random.normal(jax.random.PRNGKey(1), (N,))
    G, c = op.gram_blocked(A, b, block_rows=block_rows)
    G_ref, c_ref = _oracle(op, A, b)
    assert G.shape == (D, D) and c.shape == (D,)
    np.testing.assert_allclose(np.asarray(G), G_ref, rtol=2e-3, atol=1e-3, err_msg=kind)
    np.testing.assert_allclose(np.asarray(c), c_ref, rtol=2e-3, atol=1e-3, err_msg=kind)


@pytest.mark.parametrize("kind", ["gaussian", "rademacher", "srht", "sjlt"])
def test_kernel_gram_matches_materialized_oracle(kind):
    """The fully fused Pallas kernels (S generated in-core, accumulator in VMEM
    scratch) reproduce the dense two-pass Gram."""
    n, d, m = 200, 9, 32
    op = _op(kind, jax.random.PRNGKey(5), n=n, m=m, use_kernel=True)
    A = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    b = jax.random.normal(jax.random.PRNGKey(1), (n, 2))
    G, c = op.gram_blocked(A, b)
    G_ref, c_ref = _oracle(op, A, b)
    assert G.shape == (d, d) and c.shape == (d, 2)
    np.testing.assert_allclose(np.asarray(G), G_ref, rtol=2e-3, atol=1e-3, err_msg=kind)
    np.testing.assert_allclose(np.asarray(c), c_ref, rtol=2e-3, atol=1e-3, err_msg=kind)


@pytest.mark.parametrize("kind", ["gaussian", "rademacher", "srht", "sjlt"])
def test_kernel_gram_matches_jnp_gram(kind):
    """use_kernel=True and the jnp streaming path draw the same counter-based S,
    so their Grams agree to float tolerance."""
    n, d, m = 160, 6, 24
    A = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    key = jax.random.PRNGKey(9)
    G_k, _ = _op(kind, key, n=n, m=m, use_kernel=True).gram_blocked(A)
    G_j, _ = _op(kind, key, n=n, m=m, use_kernel=False).gram_blocked(A)
    np.testing.assert_allclose(np.asarray(G_k), np.asarray(G_j), rtol=1e-3, atol=1e-3)


def test_gram_blocked_without_b_returns_none_c():
    op = _op("gaussian", jax.random.PRNGKey(3))
    A = jax.random.normal(jax.random.PRNGKey(0), (N, D))
    G, c = op.gram_blocked(A)
    assert c is None and G.shape == (D, D)


def test_gaussian_adjoint_kernel_matches_jnp():
    """The new Gaussian adjoint kernel (matrix-free Sᵀ) == the counter-RNG jnp path."""
    n, m, k = 137, 48, 3
    key = jax.random.PRNGKey(4)
    Y = jax.random.normal(jax.random.PRNGKey(1), (m, k))
    out_k = ops.make_operator(sk.SketchSpec("gaussian", m, use_kernel=True), key, n).adjoint(Y)
    out_j = ops.make_operator(sk.SketchSpec("gaussian", m), key, n).adjoint(Y)
    assert out_k.shape == (n, k)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_j), rtol=1e-4, atol=1e-4)


def test_sketch_least_norm_kernel_path_matrix_free():
    """Right-sketch least-norm with use_kernel=True stays matrix-free end to end
    (kernel forward + the new adjoint kernel) and matches the jnp path."""
    n, d = 12, 64
    A = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    b = jax.random.normal(jax.random.PRNGKey(1), (n,))
    key = jax.random.PRNGKey(2)
    x_k = solve.sketch_least_norm(sk.SketchSpec("gaussian", 4 * n, use_kernel=True), key, A, b)
    x_j = solve.sketch_least_norm(sk.SketchSpec("gaussian", 4 * n), key, A, b)
    np.testing.assert_allclose(np.asarray(x_k), np.asarray(x_j), rtol=1e-3, atol=1e-4)


def test_double_buffered_scan_matches_reference():
    """The double-buffered row-tile scan == the plain reshape-scan reference."""
    A = jax.random.normal(jax.random.PRNGKey(0), (N, D))
    init = jnp.zeros((D,), jnp.float32)
    reducer = lambda acc, j0, Ab: acc + jnp.sum(Ab, axis=0) * (1.0 + 0.01 * j0)
    got = ops._scan_row_blocks(A, N, 33, init, reducer, double_buffer=True)
    want = ops._scan_row_blocks(A, N, 33, init, reducer, double_buffer=False)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_fused_sketch_and_solve_matches_qr_oracle():
    """method='fused' (default) solves the same sketched problem as the two-pass
    QR reference under the same key."""
    n, d, m = 1024, 12, 96
    A = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    b = jax.random.normal(jax.random.PRNGKey(1), (n,))
    key = jax.random.PRNGKey(2)
    for spec in (sk.SketchSpec("gaussian", m), sk.SketchSpec("sjlt", m, s=3)):
        x_f = solve.sketch_and_solve(spec, key, A, b)
        x_qr = solve.sketch_and_solve(spec, key, A, b, method="qr")
        np.testing.assert_allclose(np.asarray(x_f), np.asarray(x_qr), rtol=2e-3, atol=2e-4)


def test_gram_batched_matches_per_key_gram():
    """gram_batched == a Python loop of per-key gram_blocked calls."""
    q = 4
    A = jax.random.normal(jax.random.PRNGKey(0), (N, D))
    b = jax.random.normal(jax.random.PRNGKey(1), (N,))
    spec = sk.SketchSpec("gaussian", M)
    keys = prng.worker_keys(jax.random.PRNGKey(2), q)
    Gs, cs = ops.gram_batched(spec, keys, A, b)
    assert Gs.shape == (q, D, D) and cs.shape == (q, D)
    for w in range(q):
        Gw, cw = ops.gram_blocked(spec, keys[w], A, b)
        np.testing.assert_allclose(np.asarray(Gs[w]), np.asarray(Gw), rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(cs[w]), np.asarray(cw), rtol=1e-5, atol=1e-5)


def _run_subprocess(body: str, devices: int = 8, timeout: int = 900) -> str:
    script = (
        "import os\n"
        f"os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count={devices}'\n"
        "os.environ['REPRO_MESH_BATCH'] = '1'  # force the mesh path on fake devices\n"
        + textwrap.dedent(body)
    )
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True, timeout=timeout, env=env
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


@pytest.mark.subprocess
def test_apply_batched_mesh_matches_loop_bitwise():
    """shard_map-over-mesh apply_batched == the loop fallback, bitwise, under the
    same worker keys (each shard runs a lax.map over its block of keys — the exact
    computation the fallback runs over all of them)."""
    _run_subprocess(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import operators as ops, sketches as sk
        from repro.utils import prng

        n, d, m, q = 512, 8, 64, 8
        A = jax.random.normal(jax.random.PRNGKey(0), (n, d))
        keys = prng.worker_keys(jax.random.PRNGKey(1), q)
        mesh = jax.make_mesh((8,), ("workers",))
        for spec in (sk.SketchSpec("srht", m), sk.SketchSpec("gaussian", m)):
            meshed = ops.apply_batched(spec, keys, A, mesh=mesh, axis_names=("workers",))
            looped_ref = jax.lax.map(lambda k: ops.apply(spec, k, A), keys)
            np.testing.assert_array_equal(np.asarray(meshed), np.asarray(looped_ref))
            # the auto-dispatched no-mesh path (vmap or loop) agrees to float tol
            auto = ops.apply_batched(spec, keys, A)
            np.testing.assert_allclose(
                np.asarray(auto), np.asarray(looped_ref), rtol=1e-5, atol=1e-5
            )
        print("MESH_OK")
        """
    )


@pytest.mark.subprocess
def test_gram_batched_mesh_matches_loop():
    """Mesh-parallel gram_batched (what master-sketch mode ships) == loop path."""
    _run_subprocess(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import operators as ops, sketches as sk
        from repro.utils import prng

        n, d, m, q = 512, 8, 64, 8
        A = jax.random.normal(jax.random.PRNGKey(0), (n, d))
        b = jax.random.normal(jax.random.PRNGKey(2), (n,))
        keys = prng.worker_keys(jax.random.PRNGKey(1), q)
        mesh = jax.make_mesh((8,), ("workers",))
        spec = sk.SketchSpec("gaussian", m)
        Gs_m, cs_m = ops.gram_batched(spec, keys, A, b, mesh=mesh, axis_names=("workers",))
        Gs_l, cs_l = ops.gram_batched(spec, keys, A, b)
        np.testing.assert_allclose(np.asarray(Gs_m), np.asarray(Gs_l), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(cs_m), np.asarray(cs_l), rtol=1e-4, atol=1e-4)
        print("GRAM_MESH_OK")
        """
    )
