"""Tier-1 gate: the real tree is reprolint-clean.

This is the lint gate that rides every ``./test.sh`` / ``./test.sh --fast`` run:
the analyzer sweeps the actual ``src``/``tests``/``benchmarks`` trees and any
non-baselined finding fails the suite. The committed baseline is empty — new
findings must be fixed, sanctioned (``@sanctioned_wall_timer``), or suppressed
with a visible ``# reprolint: disable=<rule>`` comment, not grandfathered.
"""
from __future__ import annotations

import os

from repro.analysis import BASELINE_FILENAME, Baseline
from repro.analysis.engine import run

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _repo_paths():
    return [
        os.path.join(REPO_ROOT, p)
        for p in ("src", "tests", "benchmarks")
        if os.path.isdir(os.path.join(REPO_ROOT, p))
    ]


def test_tree_is_lint_clean():
    baseline = Baseline.load(os.path.join(REPO_ROOT, BASELINE_FILENAME))
    report = run(_repo_paths(), baseline=baseline)
    assert not report.parse_errors, report.parse_errors
    assert not report.new, "\n" + "\n".join(f.format() for f in report.new)


def test_committed_baseline_is_empty():
    """The baseline exists for adoption mechanics, but the goal state — enforced
    here — is zero grandfathered findings. Shrink it, never grow it."""
    baseline = Baseline.load(os.path.join(REPO_ROOT, BASELINE_FILENAME))
    assert len(baseline) == 0
