"""AdamW from scratch: reference math, clipping, decay masks, schedules."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import AdamWConfig, adamw_update, global_norm_clip, init_opt_state
from repro.optim.schedules import constant_schedule, linear_schedule, linear_warmup_cosine


def test_adamw_matches_reference_math():
    cfg = AdamWConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0, grad_clip=0.0)
    p = {"w": jnp.array([1.0, -2.0])}
    g = {"w": jnp.array([0.5, 0.5])}
    st = init_opt_state(cfg, p)
    newp, st, _ = adamw_update(cfg, p, g, st)
    m = 0.1 * 0.5
    v = 0.01 * 0.25
    step = (m / 0.1) / (np.sqrt(v / 0.01) + 1e-8)
    np.testing.assert_allclose(np.asarray(newp["w"]), [1.0 - 0.1 * step, -2.0 - 0.1 * step], rtol=1e-5)
    assert int(st["count"]) == 1


def test_weight_decay_decoupled_and_masked():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.5, grad_clip=0.0)
    p = {"w": jnp.ones((2,)), "norm": {"scale": jnp.ones((2,))}}
    g = {"w": jnp.zeros((2,)), "norm": {"scale": jnp.zeros((2,))}}
    st = init_opt_state(cfg, p)
    newp, *_ = adamw_update(cfg, p, g, st)
    assert float(newp["w"][0]) < 1.0           # decayed
    assert float(newp["norm"]["scale"][0]) == 1.0  # no_decay path


def test_global_norm_clip():
    g = {"a": jnp.full((4,), 3.0), "b": jnp.full((9,), 4.0)}
    clipped, norm = global_norm_clip(g, 1.0)
    total = float(
        jnp.sqrt(sum(jnp.sum(x**2) for x in jax.tree_util.tree_leaves(clipped)))
    )
    np.testing.assert_allclose(total, 1.0, rtol=1e-5)
    assert float(norm) > 1.0
    # below threshold: untouched
    clipped2, _ = global_norm_clip(g, 1e9)
    np.testing.assert_allclose(np.asarray(clipped2["a"]), np.asarray(g["a"]))


def test_bf16_moments():
    cfg = AdamWConfig(moment_dtype="bfloat16")
    p = {"w": jnp.ones((4,), jnp.bfloat16)}
    st = init_opt_state(cfg, p)
    assert st["mu"]["w"].dtype == jnp.bfloat16
    newp, st2, _ = adamw_update(cfg, p, {"w": jnp.ones((4,), jnp.bfloat16)}, st)
    assert st2["nu"]["w"].dtype == jnp.bfloat16
    assert np.isfinite(np.asarray(newp["w"], np.float32)).all()


def test_schedules():
    f = linear_warmup_cosine(10, 100, min_frac=0.1)
    assert float(f(jnp.int32(0))) == 0.0
    np.testing.assert_allclose(float(f(jnp.int32(10))), 1.0, rtol=1e-5)
    assert 0.09 < float(f(jnp.int32(100))) < 0.11
    assert float(f(jnp.int32(55))) < 1.0
    g = linear_schedule(100)
    np.testing.assert_allclose(float(g(jnp.int32(0))), 1.0)
    np.testing.assert_allclose(float(g(jnp.int32(100))), 0.0, atol=1e-6)
    assert float(constant_schedule()(jnp.int32(7))) == 1.0
