"""Direct solvers, least-norm, CG, and the IHS baseline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ihs, sketches as sk, solve


@pytest.fixture(scope="module")
def tall():
    A = jax.random.normal(jax.random.PRNGKey(0), (256, 17))
    b = jax.random.normal(jax.random.PRNGKey(1), (256,))
    return A, b


@pytest.mark.parametrize("method", ["qr", "chol", "cg"])
def test_lstsq_matches_numpy(tall, method):
    A, b = tall
    x = solve.lstsq(A, b, method=method)
    x_np, *_ = np.linalg.lstsq(np.asarray(A), np.asarray(b), rcond=None)
    tol = 1e-3 if method == "cg" else 1e-4
    np.testing.assert_allclose(np.asarray(x), x_np, rtol=tol, atol=tol)


def test_lstsq_multirhs(tall):
    A, _ = tall
    B = jax.random.normal(jax.random.PRNGKey(2), (256, 5))
    X = solve.lstsq(A, B)
    X_np, *_ = np.linalg.lstsq(np.asarray(A), np.asarray(B), rcond=None)
    np.testing.assert_allclose(np.asarray(X), X_np, rtol=1e-4, atol=1e-4)


def test_ridge_regularization(tall):
    A, b = tall
    for method in ("qr", "chol"):
        x = solve.lstsq(A, b, reg=10.0, method=method)
        # ridge solution has smaller norm than OLS
        x0 = solve.lstsq(A, b, method=method)
        assert float(jnp.linalg.norm(x)) < float(jnp.linalg.norm(x0))
        # normal-equations check: (AᵀA + λI)x = Aᵀb
        lhs = A.T @ (A @ x) + 10.0 * x
        np.testing.assert_allclose(np.asarray(lhs), np.asarray(A.T @ b), rtol=1e-3, atol=1e-3)


def test_least_norm_exactness():
    A = jax.random.normal(jax.random.PRNGKey(0), (12, 64))
    b = jax.random.normal(jax.random.PRNGKey(1), (12,))
    x = solve.least_norm(A, b)
    np.testing.assert_allclose(np.asarray(A @ x), np.asarray(b), rtol=1e-4, atol=1e-4)
    x_np = np.linalg.pinv(np.asarray(A)) @ np.asarray(b)
    np.testing.assert_allclose(np.asarray(x), x_np, rtol=1e-4, atol=1e-4)


def test_ihs_geometric_convergence(tall):
    A, b = tall
    x_star = solve.lstsq(A, b)
    f_star = float(solve.residual_cost(A, b, x_star))
    spec = sk.SketchSpec("gaussian", 8 * A.shape[1])
    trace = ihs.ihs_trace(spec, jax.random.PRNGKey(3), A, b, iters=6)
    errs = [float(solve.relative_error(A, b, trace[i], f_star)) for i in range(6)]
    # measured contraction is ~10x per iteration (0.017 -> 1.5e-6 over 6 iters)
    assert errs[-1] < 1e-4
    assert errs[-1] < errs[0] / 1000


def test_sketch_and_solve_error_reasonable(tall):
    A, b = tall
    x_star = solve.lstsq(A, b)
    f_star = float(solve.residual_cost(A, b, x_star))
    xk = solve.sketch_and_solve(sk.SketchSpec("gaussian", 8 * A.shape[1]), jax.random.PRNGKey(0), A, b)
    err = float(solve.relative_error(A, b, xk, f_star))
    assert 0 <= err < 1.0
