"""Property-testing front end: real hypothesis when installed, a deterministic
fallback otherwise.

The test image does not ship ``hypothesis`` (it is the optional ``test`` extra in
``pyproject.toml``), but the property tests in ``test_properties.py`` still have
to *run* — gating them behind ``importorskip`` silently dropped a whole test
layer. This shim keeps one import line working either way::

    from _hypo import given, settings, st

When hypothesis is importable those names are hypothesis's own. Otherwise the
fallback below draws ``max_examples`` pseudo-random examples per test from a
numpy Philox generator seeded by the test's qualified name — deterministic across
runs and machines (no ``PYTHONHASHSEED`` dependence), shrinking-free but loud on
failure (the failing example's kwargs are attached to the assertion message).

Only the strategy surface the suite uses is implemented: ``integers``,
``sampled_from``, ``lists``, ``tuples``, ``floats``, ``booleans``.
"""
from __future__ import annotations

import hashlib

try:  # pragma: no cover - exercised only on images with the `test` extra
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import numpy as np

    _DEFAULT_MAX_EXAMPLES = 100

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def sample(self, rng):
            return self._draw(rng)

    class _Strategies:
        """The subset of ``hypothesis.strategies`` the test suite draws from."""

        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def sampled_from(values):
            values = list(values)
            return _Strategy(lambda rng: values[int(rng.integers(len(values)))])

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda rng: float(min_value + (max_value - min_value) * rng.random())
            )

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(2)))

        @staticmethod
        def lists(elements, *, min_size=0, max_size=10):
            def draw(rng):
                size = int(rng.integers(min_size, max_size + 1))
                return [elements.sample(rng) for _ in range(size)]

            return _Strategy(draw)

        @staticmethod
        def tuples(*elements):
            return _Strategy(lambda rng: tuple(e.sample(rng) for e in elements))

    st = _Strategies()

    def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
        """Decorator form only (the way the suite uses it): records the example
        budget on the (already ``given``-wrapped) test function."""

        def apply(fn):
            fn._hypo_max_examples = int(max_examples)
            return fn

        return apply

    def given(**strategies):
        """Run the test once per drawn example. The RNG is seeded from the test's
        qualname, so every run (and every machine) sees the same examples."""

        def decorate(fn):
            # no functools.wraps: copying __wrapped__ would make pytest inspect
            # the original signature and demand the drawn names as fixtures
            def wrapper(*args, **kwargs):
                digest = hashlib.sha256(fn.__qualname__.encode()).digest()
                rng = np.random.default_rng(
                    np.random.Philox(int.from_bytes(digest[:8], "little"))
                )
                n = getattr(wrapper, "_hypo_max_examples", _DEFAULT_MAX_EXAMPLES)
                for i in range(n):
                    drawn = {name: s.sample(rng) for name, s in strategies.items()}
                    try:
                        fn(*args, **kwargs, **drawn)
                    except Exception as e:  # attach the failing example, no shrinking
                        raise AssertionError(
                            f"falsifying example ({i + 1}/{n}): {drawn!r}"
                        ) from e

            for attr in ("__name__", "__qualname__", "__doc__", "__module__"):
                setattr(wrapper, attr, getattr(fn, attr))
            return wrapper

        return decorate
