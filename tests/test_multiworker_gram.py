"""Multi-worker fused Gram kernels, the Rademacher family, counter-RNG knobs, and
the host-streamed out-of-core Gram.

The contract under test: ``gram_batched`` on a kernel-routed spec takes ONE
multi-worker Pallas launch whose per-worker slices are *bitwise identical* to the
q-launch per-key loop — same padding, same tile walk, same op sequence per worker.
Everything downstream (master-sketch mode, IHS) then switches paths for free.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import operators as ops, sketches as sk
from repro.kernels import common as kcommon
from repro.utils import prng

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

KERNEL_KINDS = ["gaussian", "rademacher", "sjlt", "srht"]

# Odd n, not divisible by any kernel row tile; exercises the padded last tile.
N, D, M, Q = 201, 6, 24, 3


def _spec(kind, m=M, use_kernel=True):
    if kind == "sjlt":
        return sk.SketchSpec(kind, m, s=3, use_kernel=use_kernel)
    return sk.SketchSpec(kind, m, use_kernel=use_kernel)


@pytest.mark.parametrize("kind", KERNEL_KINDS)
@pytest.mark.parametrize("with_b", [True, False])
def test_fused_multi_bitwise_matches_per_worker_loop(kind, with_b):
    """gram_batched's one-launch path == q per-key kernel launches, bitwise."""
    A = jax.random.normal(jax.random.PRNGKey(0), (N, D))
    b = jax.random.normal(jax.random.PRNGKey(1), (N,)) if with_b else None
    keys = prng.worker_keys(jax.random.PRNGKey(2), Q)
    spec = _spec(kind)
    Gs, cs = ops.gram_batched(spec, keys, A, b)
    assert Gs.shape == (Q, D, D)
    for w in range(Q):
        Gw, cw = ops.make_operator(spec, keys[w], N).gram_blocked(A, b)
        np.testing.assert_array_equal(np.asarray(Gs[w]), np.asarray(Gw), err_msg=kind)
        if with_b:
            np.testing.assert_array_equal(np.asarray(cs[w]), np.asarray(cw), err_msg=kind)
        else:
            assert cs is None


def test_fused_multi_matrix_b():
    """Multi-target b (n, k) rides through the fused multi launch unchanged."""
    A = jax.random.normal(jax.random.PRNGKey(0), (N, D))
    b = jax.random.normal(jax.random.PRNGKey(1), (N, 2))
    keys = prng.worker_keys(jax.random.PRNGKey(2), Q)
    spec = _spec("rademacher")
    Gs, cs = ops.gram_batched(spec, keys, A, b)
    assert cs.shape == (Q, D, 2)
    for w in range(Q):
        Gw, cw = ops.make_operator(spec, keys[w], N).gram_blocked(A, b)
        np.testing.assert_array_equal(np.asarray(Gs[w]), np.asarray(Gw))
        np.testing.assert_array_equal(np.asarray(cs[w]), np.asarray(cw))


def test_gram_batched_kernel_base_returns_notimplemented():
    """Kinds without a multi-worker kernel fall back to per-key dispatch."""
    assert (
        ops.SketchOp.gram_batched_kernel(sk.SketchSpec("uniform", M), None, None, None)
        is NotImplemented
    )
    # ... and gram_batched still works for them with use_kernel-less specs.
    A = jax.random.normal(jax.random.PRNGKey(0), (N, D))
    keys = prng.worker_keys(jax.random.PRNGKey(2), Q)
    Gs, cs = ops.gram_batched(sk.SketchSpec("uniform", M), keys, A)
    assert Gs.shape == (Q, D, D) and cs is None


# ------------------------------------------------------------- rademacher family


def test_rademacher_columns_match_materialized_tile():
    """The streamed columns() window (covering-word unpack at arbitrary offsets)
    == the same slice of the materialized packed-contract S."""
    op = ops.make_operator(sk.SketchSpec("rademacher", M), jax.random.PRNGKey(5), N)
    S = np.asarray(op.materialize())
    for j0, block in [(0, 32), (7, 40), (33, 64), (160, 41)]:
        tile = np.asarray(op.columns(jnp.int32(j0), block))
        np.testing.assert_array_equal(tile[:, : N - j0], S[:, j0 : j0 + block][:, : N - j0])


def test_rademacher_signs_are_packed_bits():
    """sign(i, j) = bit j%32 of threefry(key, i, j//32)[0] — the packed contract
    every consumer (jnp, kernels) shares."""
    k0, k1 = kcommon.key_to_words(jax.random.PRNGKey(5))
    rows = jnp.arange(8, dtype=jnp.uint32)[:, None]
    words = kcommon.packed_sign_words(k0, k1, rows, jnp.uint32(0))
    signs = np.asarray(
        kcommon.counter_rademacher_block(k0, k1, jnp.uint32(0), jnp.uint32(0), 8, 32)
    )
    for j in range(32):
        expect = 1.0 - 2.0 * ((np.asarray(words)[:, 0] >> j) & 1)
        np.testing.assert_array_equal(signs[:, j], expect)


def test_rademacher_kernel_sketch_matches_oracle():
    from repro.kernels.rademacher import ops as rops, ref as rref

    n, d, m = 150, 5, 40
    A = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    key = jax.random.PRNGKey(3)
    got = rops.rademacher_sketch(key, A, m)
    want = rref.rademacher_sketch(key, A, m)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_rademacher_unbiased_gram():
    """E[SᵀS] = I for the packed family: averaged Gram of S·I approaches I."""
    n, m, reps = 32, 64, 48
    keys = prng.worker_keys(jax.random.PRNGKey(9), reps)
    I = jnp.eye(n)
    spec = sk.SketchSpec("rademacher", m)
    acc = sum(np.asarray(G) for G in
              jax.vmap(lambda k: ops.gram_blocked(spec, k, I)[0])(keys))
    np.testing.assert_allclose(acc / reps, np.eye(n), atol=0.15)


# ------------------------------------------------------------ RNG rounds knob


def test_threefry_20_rounds_matches_inline_oracle():
    """The hand-rolled threefry2x32 at the default 20 rounds == an independent
    numpy transcription of the Salmon et al. reference."""

    def oracle(k0, k1, c0, c1):
        R = [[13, 15, 26, 6], [17, 29, 16, 24]]
        ks = [np.uint32(k0), np.uint32(k1), np.uint32(k0 ^ k1 ^ np.uint32(0x1BD11BDA))]
        x = [np.uint32(c0 + ks[0]), np.uint32(c1 + ks[1])]
        for block in range(5):
            for r in R[block % 2]:
                x[0] = np.uint32(x[0] + x[1])
                x[1] = np.uint32((np.uint32(x[1] << r) | np.uint32(x[1] >> (32 - r))))
                x[1] = np.uint32(x[0] ^ x[1])
            x[0] = np.uint32(x[0] + ks[(block + 1) % 3])
            x[1] = np.uint32(x[1] + ks[(block + 2) % 3] + np.uint32(block + 1))
        return x

    old = np.seterr(over="ignore")
    try:
        for k0, k1, c0, c1 in [(1, 2, 3, 4), (0, 0, 0, 0), (2**32 - 1, 7, 2**31, 5)]:
            b0, b1 = kcommon.threefry2x32(
                jnp.uint32(k0), jnp.uint32(k1), jnp.uint32(c0), jnp.uint32(c1)
            )
            w0, w1 = oracle(k0, k1, c0, c1)
            assert int(b0) == int(w0) and int(b1) == int(w1), (k0, k1, c0, c1)
    finally:
        np.seterr(**old)


def test_rng_rounds_default_and_validation():
    assert kcommon.rng_rounds() == kcommon.DEFAULT_ROUNDS == 20
    c = jnp.uint32(3)
    z_def = kcommon.counter_normal(jnp.uint32(1), jnp.uint32(2), c, c)
    z_20 = kcommon.counter_normal(jnp.uint32(1), jnp.uint32(2), c, c, rounds=20)
    assert float(z_def) == float(z_20)
    assert float(kcommon.counter_normal(jnp.uint32(1), jnp.uint32(2), c, c, rounds=8)) != float(
        z_20
    )


def _run_subprocess(body: str, env_extra: dict, timeout: int = 900) -> str:
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"), **env_extra)
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(body)],
        capture_output=True,
        text=True,
        timeout=timeout,
        env=env,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


@pytest.mark.subprocess
def test_reduced_rounds_env_knob():
    """REPRO_RNG_ROUNDS=8 (resolved at trace time, hence the subprocess): the
    gaussian kernel and jnp paths stay mutually consistent — they share the
    reduced-round stream — while the stream itself departs from the default."""
    out = _run_subprocess(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import operators as ops, sketches as sk
        from repro.kernels import common as kcommon

        assert kcommon.rng_rounds() == 8
        n, d, m = 160, 6, 24
        A = jax.random.normal(jax.random.PRNGKey(0), (n, d))
        key = jax.random.PRNGKey(9)
        G_k, _ = ops.make_operator(sk.SketchSpec("gaussian", m, use_kernel=True), key, n).gram_blocked(A)
        G_j, _ = ops.make_operator(sk.SketchSpec("gaussian", m), key, n).gram_blocked(A)
        np.testing.assert_allclose(np.asarray(G_k), np.asarray(G_j), rtol=1e-3, atol=1e-3)
        c = jnp.uint32(3)
        z8 = kcommon.counter_normal(jnp.uint32(1), jnp.uint32(2), c, c)
        z20 = kcommon.counter_normal(jnp.uint32(1), jnp.uint32(2), c, c, rounds=20)
        assert float(z8) != float(z20)
        print("ROUNDS8_OK")
        """,
        {"REPRO_RNG_ROUNDS": "8"},
    )
    assert "ROUNDS8_OK" in out


@pytest.mark.subprocess
def test_invalid_rounds_rejected():
    out = _run_subprocess(
        """
        from repro.kernels import common as kcommon
        for bad in ("6", "0", "-4", "x"):
            import os
            os.environ["REPRO_RNG_ROUNDS"] = bad
            try:
                kcommon.rng_rounds()
            except ValueError:
                pass
            else:
                raise SystemExit(f"accepted bad rounds {bad!r}")
        print("VALIDATION_OK")
        """,
        {},
    )
    assert "VALIDATION_OK" in out


# ------------------------------------------------------------- host-streamed gram


@pytest.mark.parametrize("kind", ["gaussian", "rademacher", "sjlt", "uniform"])
def test_gram_blocked_host_matches_device(kind):
    """Host-streamed out-of-core Gram == the on-device streamed Gram for block
    sizes that do not divide n, with and without b."""
    A = np.asarray(jax.random.normal(jax.random.PRNGKey(0), (N, D)))
    b = np.asarray(jax.random.normal(jax.random.PRNGKey(1), (N,)))
    key = jax.random.PRNGKey(4)
    spec = _spec(kind, use_kernel=False)
    op = ops.make_operator(spec, key, N)
    for b_ in (b, None):
        Gh, ch = ops.gram_blocked_host(spec, key, A, b_, block_rows=64)
        Gd, cd = op.gram_blocked(jnp.asarray(A), None if b_ is None else jnp.asarray(b_),
                                 block_rows=64)
        np.testing.assert_allclose(np.asarray(Gh), np.asarray(Gd), rtol=1e-4, atol=1e-4)
        if b_ is None:
            assert ch is None and cd is None
        else:
            np.testing.assert_allclose(np.asarray(ch), np.asarray(cd), rtol=1e-4, atol=1e-4)


def test_gram_blocked_host_memmap(tmp_path):
    """np.memmap input: the stream never loads all of A — the shipping case."""
    A = np.asarray(jax.random.normal(jax.random.PRNGKey(0), (N, D)), np.float32)
    path = tmp_path / "A.bin"
    mm = np.memmap(path, dtype=np.float32, mode="w+", shape=(N, D))
    mm[:] = A
    mm.flush()
    ro = np.memmap(path, dtype=np.float32, mode="r", shape=(N, D))
    spec = sk.SketchSpec("rademacher", M)
    key = jax.random.PRNGKey(4)
    Gm, _ = ops.gram_blocked_host(spec, key, ro, block_rows=50)
    Ga, _ = ops.gram_blocked_host(spec, key, A, block_rows=50)
    np.testing.assert_array_equal(np.asarray(Gm), np.asarray(Ga))


def test_gram_blocked_host_single_tile():
    """block_rows >= n: one tile, no prefetch loop — still correct."""
    A = np.asarray(jax.random.normal(jax.random.PRNGKey(0), (64, D)))
    spec = sk.SketchSpec("gaussian", M)
    key = jax.random.PRNGKey(4)
    Gh, _ = ops.gram_blocked_host(spec, key, A, block_rows=4096)
    Gd, _ = ops.make_operator(spec, key, 64).gram_blocked(jnp.asarray(A))
    np.testing.assert_allclose(np.asarray(Gh), np.asarray(Gd), rtol=1e-5, atol=1e-5)


# -------------------------------------------------------------- misc satellites


def test_hadamard_matrix_cached():
    """The host-side popcount construction is cached per (k, dtype), and calling
    under a jit trace must not poison the cache with a leaked tracer."""
    assert kcommon._hadamard_cached(16, "float32") is kcommon._hadamard_cached(16, "float32")
    assert isinstance(kcommon._hadamard_cached(16, "float32"), np.ndarray)
    H = kcommon.hadamard_matrix(16, jnp.float32)
    np.testing.assert_array_equal(np.asarray(H).T @ np.asarray(H), 16 * np.eye(16))
    traced = jax.jit(lambda: kcommon.hadamard_matrix(16, jnp.float32))()
    np.testing.assert_array_equal(np.asarray(traced), np.asarray(H))
    post = kcommon.hadamard_matrix(16, jnp.float32)  # after a trace: still concrete
    np.testing.assert_array_equal(np.asarray(post), np.asarray(H))
    with pytest.raises(ValueError):
        kcommon.hadamard_matrix(12, jnp.float32)


def test_prng_reexports_are_kernel_common():
    """utils.prng re-exports the single source of truth in kernels.common."""
    assert prng.bits_to_open_unit is kcommon.bits_to_open_unit
    assert prng.counter_normal is kcommon.counter_normal
    assert prng.counter_rademacher is kcommon.counter_rademacher
    assert prng.counter_rademacher_block is kcommon.counter_rademacher_block
