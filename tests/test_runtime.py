"""Runtime subsystem: deterministic replay, retries, early stop, mask equivalence,
all-straggler contract, multiround trace hoisting, trainer delegation, and the
cross-backend determinism contract (inline == thread == process, any pool width)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import runtime as rt
from repro.core import distributed, sketches as sk, solve
from repro.utils import prng

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ------------------------------------------------------------------ latency models


def test_latency_models_deterministic_and_distinct():
    for model in (
        rt.LognormalLatency(seed=3, mean_s=0.5, sigma=0.4),
        rt.HeavyTailLatency(seed=3, scale_s=0.5, alpha=1.5),
        rt.DropLatency(seed=3, inner=rt.LognormalLatency(seed=3), drop_prob=0.3),
    ):
        a = model.sample_wave(16, round_id=2)
        b = model.sample_wave(16, round_id=2)
        np.testing.assert_array_equal(a, b)  # pure function of the coordinate
        assert not np.array_equal(a, model.sample_wave(16, round_id=3))
        # retries are fresh draws, not replays
        assert not np.array_equal(a, model.sample_wave(16, round_id=2, attempt=1))


def test_drop_latency_rate_and_inner_stream():
    inner = rt.LognormalLatency(seed=9, mean_s=1.0, sigma=0.2)
    drop = rt.DropLatency(seed=9, inner=inner, drop_prob=0.4)
    wave = drop.sample_wave(512)
    frac_inf = np.isinf(wave).mean()
    assert 0.3 < frac_inf < 0.5
    # surviving draws equal the inner model's draws (distinct salt, same stream)
    finite = ~np.isinf(wave)
    np.testing.assert_array_equal(wave[finite], inner.sample_wave(512)[finite])


def test_lognormal_quantile_matches_empirical():
    model = rt.LognormalLatency(seed=1, mean_s=2.0, sigma=0.5)
    cut = model.quantile(0.8)
    frac = (model.sample_wave(4096) <= cut).mean()
    assert abs(frac - 0.8) < 0.03


# ------------------------------------------------------------------ engine core


def _toy_problem(n=512, d=8):
    key = jax.random.PRNGKey(0)
    A = jax.random.normal(key, (n, d))
    b = A @ jax.random.normal(jax.random.PRNGKey(1), (d,)) + 0.5 * jax.random.normal(
        jax.random.PRNGKey(2), (n,)
    )
    return key, A, b


def test_engine_deterministic_replay(tmp_path):
    """Same seed ⇒ byte-identical event log and bitwise-identical x̄."""
    key, A, b = _toy_problem()
    spec = sk.SketchSpec("gaussian", 64)
    lat = rt.DropLatency(
        seed=11, inner=rt.LognormalLatency(seed=11, mean_s=0.4, sigma=0.6), drop_prob=0.2
    )
    cfg = rt.RuntimeConfig(deadline_s=0.5, max_retries=2, backoff_base_s=0.05)

    runs = [
        rt.serverless_sketch_solve(spec, key, A, b, q=8, latency=lat, config=cfg)
        for _ in range(2)
    ]
    assert runs[0].events.lines() == runs[1].events.lines()
    np.testing.assert_array_equal(runs[0].xbar, runs[1].xbar)
    assert runs[0].arrived == runs[1].arrived

    # JSONL round-trips through disk unchanged
    p = tmp_path / "events.jsonl"
    runs[0].events.to_jsonl(str(p))
    assert p.read_text().splitlines() == runs[0].events.lines()


def test_engine_welford_average_is_exact_masked_mean():
    """The streaming average equals the plain mean of exactly the arrived results."""
    key, A, b = _toy_problem()
    spec = sk.SketchSpec("gaussian", 64)
    lat = rt.LognormalLatency(seed=5, mean_s=0.4, sigma=0.7)
    cfg = rt.RuntimeConfig(deadline_s=0.45, max_retries=0)
    res = rt.serverless_sketch_solve(spec, key, A, b, q=16, latency=lat, config=cfg)
    assert 0 < res.count < 16  # deadline at ~median: some arrive, some miss
    xs = np.stack(
        [
            np.asarray(solve.sketch_and_solve(spec, prng.worker_key(key, w, r), A, b))
            for (w, r, _) in res.arrived
        ]
    )
    np.testing.assert_allclose(res.xbar, xs.mean(0), rtol=1e-6, atol=1e-6)
    # realized_mask marks exactly the attempt-0 arrivals
    assert res.realized_mask.sum() == res.count


def test_engine_retries_are_fresh_rounds():
    key, A, b = _toy_problem()
    spec = sk.SketchSpec("gaussian", 64)
    # median 1.0 » deadline: most first attempts time out, retries eventually land
    lat = rt.LognormalLatency(seed=21, mean_s=1.0, sigma=1.5)
    cfg = rt.RuntimeConfig(deadline_s=0.6, max_retries=4, backoff_base_s=0.1)
    res = rt.serverless_sketch_solve(spec, key, A, b, q=8, latency=lat, config=cfg)

    counts = res.events.counts()
    assert counts.get("timeout", 0) > 0 and counts.get("retry", 0) > 0
    assert res.dispatched == 8 + counts["retry"]
    # every retried attempt carries a round_id outside the initial wave's range,
    # and no (worker, round) coordinate is ever dispatched twice — new i.i.d.
    # sketches, never replays
    dispatches = [ev for ev in res.events if ev.kind == "dispatch"]
    coords = [(ev.worker_id, ev.round_id) for ev in dispatches]
    assert len(coords) == len(set(coords))
    assert all(ev.round_id >= 1 for ev in dispatches if ev.attempt > 0)
    # backoff: the attempt-(a+1) dispatch happens strictly after attempt a timed out
    t_timeout = {(ev.task_id, ev.attempt): ev.t for ev in res.events if ev.kind == "timeout"}
    for ev in dispatches:
        if ev.attempt > 0:
            assert ev.t > t_timeout[(ev.task_id, ev.attempt - 1)]


def test_engine_early_stop_on_theory_target():
    key, A, b = _toy_problem(n=1024, d=16)
    spec = sk.SketchSpec("gaussian", 128)
    single = 16 / (128 - 16 - 1)  # Lemma 1
    target = single / 8  # reachable after exactly 8 arrivals
    cfg = rt.RuntimeConfig(deadline_s=10.0, max_retries=0, target_error=target)
    res = rt.serverless_sketch_solve(
        spec, key, A, b, q=32,
        latency=rt.ConstantLatency(seed=0, value_s=0.1),
        config=cfg, error_fn="theory",
    )
    assert res.stopped_early
    assert res.count == 8 and res.submitted == 32
    assert res.final_error <= target
    counts = res.events.counts()
    assert counts["stop"] == 1 and counts["cancel"] == 32 - 8


def test_engine_all_dropped_raises():
    key, A, b = _toy_problem()
    spec = sk.SketchSpec("gaussian", 64)
    lat = rt.DropLatency(seed=0, inner=rt.ConstantLatency(value_s=0.1), drop_prob=1.0)
    eng = rt.ServerlessEngine(
        rt.make_sketch_solve_compute(spec, key, A, b), lat, rt.RuntimeConfig(max_retries=1)
    )
    with pytest.raises(RuntimeError, match="no worker result"):
        eng.run(q=4)


def test_engine_summary_and_error_trace():
    key, A, b = _toy_problem(n=1024, d=16)
    spec = sk.SketchSpec("gaussian", 128)
    cfg = rt.RuntimeConfig(deadline_s=10.0, max_retries=0)
    res = rt.serverless_sketch_solve(
        spec, key, A, b, q=8,
        latency=rt.LognormalLatency(seed=2, mean_s=0.3), config=cfg, error_fn="probe",
    )
    trace = res.events.error_trace()
    assert len(trace) == res.count == 8
    ts = [t for t, _, _ in trace]
    assert ts == sorted(ts)  # arrival order = simulated time order
    assert trace[-1][1] == 8
    s = res.summary(deadline=cfg.deadline_s)
    assert s["effective_q"] == 8 and s["count"] == 8
    assert s["p50_latency_s"] <= s["p95_latency_s"]
    hb = s["heartbeat"]
    assert hb["effective_q"] == 8.0 and "p50_runtime" in hb


# ------------------------------------------------------------ executor backends


def _backend_scenario():
    """A run with drops, timeouts, and retries — the kind of schedule where a
    backend that leaked wall-clock ordering into the event log would diverge."""
    key, A, b = _toy_problem()
    spec = sk.SketchSpec("gaussian", 64)
    lat = rt.DropLatency(
        seed=23, inner=rt.LognormalLatency(seed=23, mean_s=0.4, sigma=0.6), drop_prob=0.2
    )
    return key, A, b, spec, lat


def test_backend_inline_matches_thread():
    """Same seed ⇒ byte-identical event log + bitwise x̄ on inline vs thread."""
    key, A, b, spec, lat = _backend_scenario()
    cfg = rt.RuntimeConfig(deadline_s=0.5, max_retries=2, backoff_base_s=0.05)
    runs = {
        kind: rt.serverless_sketch_solve(
            spec, key, A, b, q=8, latency=lat, config=cfg, backend=kind
        )
        for kind in ("inline", "thread")
    }
    assert runs["inline"].events.lines() == runs["thread"].events.lines()
    np.testing.assert_array_equal(runs["inline"].xbar, runs["thread"].xbar)
    assert runs["inline"].arrived == runs["thread"].arrived


def test_backend_thread_pool_width_is_invisible():
    """Event order comes from the simulated clock, never thread scheduling: a
    1-wide and an 8-wide pool replay the identical run."""
    key, A, b, spec, lat = _backend_scenario()
    runs = [
        rt.serverless_sketch_solve(
            spec, key, A, b, q=8, latency=lat,
            config=rt.RuntimeConfig(
                deadline_s=0.5, max_retries=2, backoff_base_s=0.05, max_threads=width
            ),
        )
        for width in (1, 8)
    ]
    assert runs[0].events.lines() == runs[1].events.lines()
    np.testing.assert_array_equal(runs[0].xbar, runs[1].xbar)


@pytest.mark.slow
@pytest.mark.subprocess
def test_backend_process_matches_inline_across_pool_sizes():
    """The process backend (real OS worker processes, spawn) replays the same
    bytes as inline, for 1- and 2-wide pools — the acceptance contract."""
    key, A, b, spec, lat = _backend_scenario()
    cfg = rt.RuntimeConfig(deadline_s=0.5, max_retries=2, backoff_base_s=0.05)
    ref = rt.serverless_sketch_solve(
        spec, key, A, b, q=8, latency=lat, config=cfg, backend="inline"
    )
    import dataclasses

    for width in (1, 2):
        res = rt.serverless_sketch_solve(
            spec, key, A, b, q=8, latency=lat,
            config=dataclasses.replace(cfg, max_threads=width), backend="process",
        )
        assert res.events.lines() == ref.events.lines(), f"pool width {width}"
        np.testing.assert_array_equal(res.xbar, ref.xbar)


def test_engine_reuses_caller_owned_backend_instance():
    """An ExecutorBackend instance passes through make_backend untouched and the
    engine never shuts it down — it survives (and replays across) multiple runs."""
    key, A, b, spec, lat = _backend_scenario()
    compute = rt.make_sketch_solve_compute(spec, key, A, b)
    shared = rt.ThreadBackend(compute, max_workers=2)
    assert rt.make_backend(shared, compute) is shared
    cfg = rt.RuntimeConfig(deadline_s=0.5, max_retries=1)
    eng = rt.ServerlessEngine(compute, lat, cfg, backend=shared)
    a, bb = eng.run(q=4), eng.run(q=4)
    assert a.events.lines() == bb.events.lines()
    shared.shutdown()


# ---------------------------------------------------------- adaptive deadlines


def test_adaptive_deadline_recovers_from_misset_static():
    """A static deadline below the latency median burns its retry budget on
    timeouts; the adaptive policy reads the timeout stream, escalates past the
    median, and lands strictly more results with fewer timeouts."""
    key, A, b = _toy_problem()
    spec = sk.SketchSpec("gaussian", 64)
    lat = rt.LognormalLatency(seed=11, mean_s=1.0, sigma=0.4)
    cfg = rt.RuntimeConfig(deadline_s=0.6, max_retries=3, backoff_base_s=0.05)
    static = rt.serverless_sketch_solve(spec, key, A, b, q=8, latency=lat, config=cfg)
    adaptive = [
        rt.serverless_sketch_solve(
            spec, key, A, b, q=8, latency=lat, config=cfg,
            deadline=rt.AdaptiveDeadline(warmup_s=0.6, min_samples=3),
        )
        for _ in range(2)
    ]
    assert adaptive[0].count > static.count
    assert (
        adaptive[0].events.counts().get("timeout", 0)
        < static.events.counts().get("timeout", 0)
    )
    # the adaptive tracker sits inside the replay loop: still fully deterministic
    assert adaptive[0].events.lines() == adaptive[1].events.lines()
    np.testing.assert_array_equal(adaptive[0].xbar, adaptive[1].xbar)
    # dispatch events carry the effective deadline; retries escalate beyond warmup
    dls = [
        ev.extra["deadline_s"]
        for ev in adaptive[0].events
        if ev.kind == "dispatch" and ev.attempt > 0
    ]
    assert dls and max(dls) > 0.6


def test_deadline_policy_resolution_and_float_shorthand():
    cfg = rt.RuntimeConfig(deadline_s=0.7)
    assert rt.resolve_deadline_policy(None, cfg).start().current() == 0.7
    assert rt.resolve_deadline_policy(1.3, cfg).start().current() == 1.3
    pol = rt.AdaptiveDeadline(warmup_s=2.0)
    assert rt.resolve_deadline_policy(pol, cfg) is pol
    assert pol.start().current() == 2.0  # warm-up before min_samples


def test_straggler_policy_bridges_to_deadline_policy():
    from repro.distributed.fault_tolerance import StragglerPolicy

    pol = StragglerPolicy(deadline_quantile=0.8, seed=0)
    static = pol.to_deadline_policy(mean_s=1.0, sigma=0.35)
    assert isinstance(static, rt.StaticDeadline)
    expected = rt.LognormalLatency(mean_s=1.0, sigma=0.35).quantile(0.8)
    assert static.deadline_s == pytest.approx(expected)
    adaptive = pol.to_deadline_policy(mean_s=1.0, sigma=0.35, adaptive=True)
    assert isinstance(adaptive, rt.AdaptiveDeadline)
    assert adaptive.warmup_s == pytest.approx(expected)
    assert adaptive.quantile == 0.8
    # keep-everyone policy: infinite static cutoff, finite adaptive warm-up
    keep = StragglerPolicy(deadline_quantile=1.0)
    import math

    assert math.isinf(keep.to_deadline_policy().deadline_s)
    assert math.isfinite(keep.to_deadline_policy(adaptive=True).warmup_s)


# -------------------------------------------------- runtime vs synchronous mesh


@pytest.mark.subprocess
def test_runtime_matches_masked_distributed_solve():
    """Async run with latency injection == distributed_sketch_solve with the
    realized mask, for gaussian / sjlt / hybrid (subprocess: 8-device mesh)."""
    script = textwrap.dedent(
        """
        import os
        os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
        import jax, jax.numpy as jnp, numpy as np
        from repro import runtime as rt
        from repro.core import distributed, sketches as sk

        key = jax.random.PRNGKey(0)
        n, d = 2048, 16
        A = jax.random.normal(key, (n, d))
        b = jax.random.normal(jax.random.PRNGKey(1), (n,))
        mesh = jax.make_mesh((8,), ("data",))

        for spec in [
            sk.SketchSpec("gaussian", 128),
            sk.SketchSpec("sjlt", 128, s=4),
            sk.SketchSpec("hybrid", 128, m_prime=512),
        ]:
            lat = rt.DropLatency(
                seed=13, inner=rt.LognormalLatency(seed=13, mean_s=0.5, sigma=0.6),
                drop_prob=0.2,
            )
            cfg = rt.RuntimeConfig(deadline_s=0.55, max_retries=0)
            res = rt.serverless_sketch_solve(spec, key, A, b, q=8, latency=lat, config=cfg)
            mask = res.realized_mask
            assert 0 < mask.sum() < 8, (spec.kind, mask)
            xbar = distributed.distributed_sketch_solve(
                mesh, spec, key, A, b, straggler_mask=jnp.asarray(mask))
            np.testing.assert_allclose(
                np.asarray(xbar), res.xbar, rtol=1e-4, atol=1e-4,
                err_msg=spec.kind)
        print("RUNTIME_EQUIV_OK")
        """
    )
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True, timeout=900, env=env
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    assert "RUNTIME_EQUIV_OK" in out.stdout


# ------------------------------------------------------- all-straggler contract


def _small_lsq():
    key = jax.random.PRNGKey(0)
    A = jax.random.normal(key, (256, 8))
    b = jax.random.normal(jax.random.PRNGKey(1), (256,))
    return key, A, b


def test_all_straggler_eager_mask_raises():
    key, A, b = _small_lsq()
    mesh = jax.make_mesh((1,), ("data",))
    spec = sk.SketchSpec("gaussian", 64)
    zero = jnp.zeros((1,), jnp.float32)
    for call in (
        lambda: distributed.distributed_sketch_solve(mesh, spec, key, A, b, straggler_mask=zero),
        lambda: distributed.distributed_sketch_solve_master(mesh, spec, key, A, b, straggler_mask=zero),
        lambda: distributed.distributed_sketch_solve_master(
            mesh, spec, key, A, b, straggler_mask=zero, method="qr"
        ),
        lambda: distributed.distributed_sketch_least_norm(
            mesh, sk.SketchSpec("gaussian", 32), key, A[:4, :], b[:4], straggler_mask=zero
        ),
    ):
        with pytest.raises(ValueError, match="no surviving workers"):
            call()


def test_all_straggler_traced_mask_nan_poisons():
    key, A, b = _small_lsq()
    mesh = jax.make_mesh((1,), ("data",))
    spec = sk.SketchSpec("gaussian", 64)
    zero = jnp.zeros((1,), jnp.float32)
    ones = jnp.ones((1,), jnp.float32)

    f = jax.jit(
        lambda m: distributed.distributed_sketch_solve(mesh, spec, key, A, b, straggler_mask=m)
    )
    assert np.isnan(np.asarray(f(zero))).all()
    assert np.isfinite(np.asarray(f(ones))).all()  # non-empty rounds unaffected

    f_zero = jax.jit(
        lambda m: distributed.distributed_sketch_solve(
            mesh, spec, key, A, b, straggler_mask=m, on_empty="zero"
        )
    )
    np.testing.assert_array_equal(np.asarray(f_zero(zero)), 0.0)  # legacy opt-in

    f_master = jax.jit(
        lambda m: distributed.distributed_sketch_solve_master(
            mesh, spec, key, A, b, straggler_mask=m
        )
    )
    assert np.isnan(np.asarray(f_master(zero))).all()

    An, bn = A[:4, :], b[:4]  # n=4 < d=8 for the least-norm variant
    f_ln = jax.jit(
        lambda m: distributed.distributed_sketch_least_norm(
            mesh, sk.SketchSpec("gaussian", 32), key, An, bn, straggler_mask=m
        )
    )
    assert np.isnan(np.asarray(f_ln(zero))).all()


# --------------------------------------------------------- multiround hoisting


def test_multiround_traces_once_and_matches_reference():
    key, A, b = _small_lsq()
    mesh = jax.make_mesh((1,), ("data",))
    spec = sk.SketchSpec("gaussian", 64)
    rounds = 4

    before = distributed.MULTIROUND_TRACE_COUNT
    xbar = distributed.distributed_sketch_solve_multiround(
        mesh, spec, key, A, b, rounds=rounds
    )
    assert distributed.MULTIROUND_TRACE_COUNT == before + 1  # 1 trace, not `rounds`

    xs = np.stack(
        [
            np.asarray(solve.sketch_and_solve(spec, prng.worker_key(key, 0, r), A, b))
            for r in range(rounds)
        ]
    )
    np.testing.assert_allclose(np.asarray(xbar), xs.mean(0), rtol=1e-4, atol=1e-5)


def test_multiround_latency_delegates_to_engine():
    """latency= makes multiround a thin wrapper over the async engine; with a
    no-straggler model it reproduces the synchronous result."""
    key, A, b = _small_lsq()
    mesh = jax.make_mesh((1,), ("data",))
    spec = sk.SketchSpec("gaussian", 64)
    sync = distributed.distributed_sketch_solve_multiround(mesh, spec, key, A, b, rounds=3)
    asyn = distributed.distributed_sketch_solve_multiround(
        mesh, spec, key, A, b, rounds=3,
        latency=rt.ConstantLatency(value_s=0.01),
        runtime_config=rt.RuntimeConfig(deadline_s=1.0, max_retries=0),
    )
    np.testing.assert_allclose(np.asarray(asyn), np.asarray(sync), rtol=1e-4, atol=1e-5)


# ------------------------------------------------------------ trainer delegation


def test_trainer_delegates_straggler_simulation_to_runtime():
    import dataclasses

    from repro.configs.base import get_config
    from repro.optim import AdamWConfig
    from repro.train import Trainer, TrainerConfig

    cfg = dataclasses.replace(
        get_config("granite-3-8b").reduced(), num_layers=1, d_model=16, d_ff=32,
        num_heads=2, num_kv_heads=1, head_dim=8, vocab_size=31,
    )

    def step_fn(state, batch, mask):
        return {"step": state["step"] + 1}, {"loss": jnp.float32(0.0), "qprime": mask.sum()}

    def run_once(seed):
        tc = TrainerConfig(
            batch=2, seq=8, log_every=1,
            latency=rt.LognormalLatency(seed=seed, mean_s=1.0, sigma=0.5),
            straggler_q=8, deadline_s=1.0,
        )
        tr = Trainer(cfg, AdamWConfig(lr=1e-3), tc, step_fn=step_fn)
        tr.run(5, state={"step": jnp.int32(0)})
        return tr

    tr_a, tr_b = run_once(7), run_once(7)
    qa = [h["qprime"] for h in tr_a.history]
    qb = [h["qprime"] for h in tr_b.history]
    assert qa == qb  # restart-deterministic straggler pattern
    assert any(q < 8 for q in qa)  # the deadline actually bites
    rep = tr_a.straggler_report()
    assert rep["steps"] == 5.0
    assert {"p50_runtime", "timeouts", "retries", "effective_q"} <= set(rep)
    assert rep["timeouts"] == sum(8 - q for q in qa)
    # a different latency seed sees a different pattern
    assert [h["qprime"] for h in run_once(8).history] != qa
