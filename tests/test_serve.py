"""Serving engine: batching equivalence, determinism, EOS trimming."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.models import lm
from repro.serve import Engine, ServeConfig


def _setup(max_batch=4):
    cfg = dataclasses.replace(
        get_config("granite-3-8b").reduced(), num_layers=2, d_model=32, d_ff=64,
        num_heads=2, num_kv_heads=1, head_dim=16, vocab_size=97,
    )
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    engine = Engine(cfg, params, ServeConfig(max_batch=max_batch, max_len=64))
    return cfg, params, engine


def test_generate_shapes_and_determinism():
    _, _, engine = _setup()
    prompts = [[1, 2, 3, 4], [5, 6, 7, 8]]
    a = engine.generate(prompts, max_new_tokens=6)
    b = engine.generate(prompts, max_new_tokens=6)
    assert a == b
    assert len(a) == 2 and all(len(o) == 6 for o in a)
    cfg = engine.cfg
    assert all(t < cfg.vocab_size for o in a for t in o)  # padded ids masked


def test_batched_equals_rectangular_single():
    """Greedy decode of equal-length prompts must not depend on batch packing."""
    _, _, engine = _setup()
    p1, p2 = [3, 1, 4, 1], [2, 7, 1, 8]
    both = engine.generate([p1, p2], max_new_tokens=5)
    solo1 = engine.generate([p1], max_new_tokens=5)
    solo2 = engine.generate([p2], max_new_tokens=5)
    assert both[0] == solo1[0]
    assert both[1] == solo2[0]


def test_multi_chunk_queue():
    _, _, engine = _setup(max_batch=2)
    prompts = [[i + 1, i + 2, i + 3] for i in range(5)]  # 3 engine batches
    outs = engine.generate(prompts, max_new_tokens=4)
    assert len(outs) == 5


def test_eos_trimming():
    cfg, params, _ = _setup()
    engine = Engine(cfg, params, ServeConfig(max_batch=2, max_len=64, eos_id=0))
    outs = engine.generate([[1, 2, 3]], max_new_tokens=8)
    row = outs[0]
    if 0 in row:
        assert row[-1] == 0 and 0 not in row[:-1]
