"""Serving engines: LM batching equivalence, determinism, EOS trimming — and the
sketch-solve job-admission path (SolveServer.submit_solve)."""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models import lm
from repro.serve import Engine, ServeConfig, SolveServer

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _setup(max_batch=4):
    cfg = dataclasses.replace(
        get_config("granite-3-8b").reduced(), num_layers=2, d_model=32, d_ff=64,
        num_heads=2, num_kv_heads=1, head_dim=16, vocab_size=97,
    )
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    engine = Engine(cfg, params, ServeConfig(max_batch=max_batch, max_len=64))
    return cfg, params, engine


def test_generate_shapes_and_determinism():
    _, _, engine = _setup()
    prompts = [[1, 2, 3, 4], [5, 6, 7, 8]]
    a = engine.generate(prompts, max_new_tokens=6)
    b = engine.generate(prompts, max_new_tokens=6)
    assert a == b
    assert len(a) == 2 and all(len(o) == 6 for o in a)
    cfg = engine.cfg
    assert all(t < cfg.vocab_size for o in a for t in o)  # padded ids masked


def test_batched_equals_rectangular_single():
    """Greedy decode of equal-length prompts must not depend on batch packing."""
    _, _, engine = _setup()
    p1, p2 = [3, 1, 4, 1], [2, 7, 1, 8]
    both = engine.generate([p1, p2], max_new_tokens=5)
    solo1 = engine.generate([p1], max_new_tokens=5)
    solo2 = engine.generate([p2], max_new_tokens=5)
    assert both[0] == solo1[0]
    assert both[1] == solo2[0]


def test_multi_chunk_queue():
    _, _, engine = _setup(max_batch=2)
    prompts = [[i + 1, i + 2, i + 3] for i in range(5)]  # 3 engine batches
    outs = engine.generate(prompts, max_new_tokens=4)
    assert len(outs) == 5


def test_eos_trimming():
    cfg, params, _ = _setup()
    engine = Engine(cfg, params, ServeConfig(max_batch=2, max_len=64, eos_id=0))
    outs = engine.generate([[1, 2, 3]], max_new_tokens=8)
    row = outs[0]
    if 0 in row:
        assert row[-1] == 0 and 0 not in row[:-1]


# ------------------------------------------------------- sketch-solve admission


def _solve_problem(n=1024, d=16):
    key = jax.random.PRNGKey(0)
    A = jax.random.normal(key, (n, d))
    b = A @ jax.random.normal(jax.random.PRNGKey(1), (d,)) + 0.3 * jax.random.normal(
        jax.random.PRNGKey(2), (n,)
    )
    return key, A, b


def test_submit_solve_deterministic_and_telemetry(tmp_path):
    """Repeat submissions of the same seeded job are bitwise-identical; each job
    leaves a complete telemetry record and the aggregate report sums them."""
    from repro import runtime as rt
    from repro.core import sketches as sk

    _, A, b = _solve_problem()
    spec = sk.SketchSpec("gaussian", 128)
    lat = rt.DropLatency(
        seed=19, inner=rt.LognormalLatency(seed=19, mean_s=0.4, sigma=0.6), drop_prob=0.2
    )
    server = SolveServer(
        latency=lat,
        config=rt.RuntimeConfig(deadline_s=0.5, max_retries=2, backoff_base_s=0.05),
    )
    p = tmp_path / "job0.jsonl"
    j0 = server.submit_solve(A, b, spec, q=8, seed=4, save_events=str(p))
    j1 = server.submit_solve(A, b, spec, q=8, seed=4)
    np.testing.assert_array_equal(j0.xbar, j1.xbar)
    assert j0.result.events.lines() == j1.result.events.lines()
    assert p.read_text().splitlines() == j0.result.events.lines()

    assert j0.job_id == 0 and j1.job_id == 1 and j0.backend == "thread"
    assert j0.summary["effective_q"] == j0.result.count
    np.testing.assert_array_equal(j0.realized_mask, j0.result.realized_mask)

    agg = server.telemetry()
    assert agg["jobs"] == 2 and agg["backend"] == "thread"
    assert agg["retries"] == 2 * j0.summary["retries"]
    assert agg["effective_q_mean"] == pytest.approx(j0.summary["effective_q"])
    assert [pj["job_id"] for pj in agg["per_job"]] == [0, 1]


def test_submit_solve_early_stop_and_rounds():
    """target_error + error_fn stop a multi-round job early; the error trace is
    monotone in arrivals and the stop is recorded in the job summary."""
    from repro import runtime as rt
    from repro.core import sketches as sk

    _, A, b = _solve_problem()
    spec = sk.SketchSpec("gaussian", 128)
    single = 16 / (128 - 16 - 1)  # Lemma 1 for d=16, m=128
    server = SolveServer(
        latency=rt.ConstantLatency(seed=0, value_s=0.1),
        config=rt.RuntimeConfig(deadline_s=10.0, max_retries=0, target_error=single / 8),
    )
    job = server.submit_solve(A, b, spec, q=16, rounds=2, error_fn="theory")
    assert job.summary["stopped_early"]
    assert job.result.count == 8 and job.result.submitted == 32
    assert server.telemetry()["stopped_early"] == 1


@pytest.mark.subprocess
def test_submit_solve_matches_masked_distributed_solve():
    """The serve path reproduces the synchronous mesh solve: submit_solve with a
    latency model == distributed_sketch_solve with the realized mask, for
    gaussian / sjlt (subprocess: 8-device mesh; rtol matches the runtime
    equivalence tests — engine averages in float64, psum in float32)."""
    script = textwrap.dedent(
        """
        import os
        os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
        import jax, jax.numpy as jnp, numpy as np
        from repro import runtime as rt
        from repro.core import distributed, sketches as sk
        from repro.serve import SolveServer

        key = jax.random.PRNGKey(0)
        n, d = 2048, 16
        A = jax.random.normal(key, (n, d))
        b = jax.random.normal(jax.random.PRNGKey(1), (n,))
        mesh = jax.make_mesh((8,), ("data",))

        for spec in [sk.SketchSpec("gaussian", 128), sk.SketchSpec("sjlt", 128, s=4)]:
            lat = rt.DropLatency(
                seed=13, inner=rt.LognormalLatency(seed=13, mean_s=0.5, sigma=0.6),
                drop_prob=0.2,
            )
            server = SolveServer(
                latency=lat, config=rt.RuntimeConfig(deadline_s=0.55, max_retries=0)
            )
            job = server.submit_solve(A, b, spec, q=8, key=key)
            mask = job.realized_mask
            assert 0 < mask.sum() < 8, (spec.kind, mask)
            xbar = distributed.distributed_sketch_solve(
                mesh, spec, key, A, b, straggler_mask=jnp.asarray(mask))
            np.testing.assert_allclose(
                np.asarray(xbar), job.xbar, rtol=1e-4, atol=1e-4, err_msg=spec.kind)
        print("SERVE_EQUIV_OK")
        """
    )
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True, timeout=900, env=env
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    assert "SERVE_EQUIV_OK" in out.stdout
