"""Per-arch smoke + layer-level references (MoE dispatch, SSM scan, attention)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED
from repro.configs.base import get_config
from repro.models import attention, layers, lm, moe as moe_lib, ssm as ssm_lib
from repro.optim import AdamWConfig
from repro.train.state import init_train_state
from repro.train.step import make_train_step


def _batch(cfg, key, B=2, S=16):
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "loss_mask": jnp.ones((B, S), jnp.float32),
    }
    if cfg.vlm:
        batch["patches"] = jax.random.normal(key, (B, cfg.num_image_tokens, cfg.vit_dim), jnp.float32)
    if cfg.encdec:
        batch["frames"] = jax.random.normal(key, (B, cfg.enc_seq, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_arch_smoke_one_train_step(arch):
    """REQUIRED smoke test: reduced config, one forward+backward+update on CPU,
    asserting output shapes and no NaNs."""
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    state = init_train_state(cfg, AdamWConfig(lr=1e-3), key)
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3)))
    batch = _batch(cfg, key)
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(new_state["step"]) == 1
    # params actually changed & stayed finite
    before = jax.tree_util.tree_leaves(state["params"])
    after = jax.tree_util.tree_leaves(new_state["params"])
    changed = any(not np.array_equal(np.asarray(a), np.asarray(b)) for a, b in zip(before, after))
    assert changed
    assert all(np.isfinite(np.asarray(x, dtype=np.float32)).all() for x in after)


@pytest.mark.parametrize("arch", ASSIGNED)
def test_arch_forward_shapes(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key)
    B, S = 2, 12
    batch = _batch(cfg, key, B, S)
    logits = lm.forward_logits(params, cfg, batch)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits)).all()


def test_moe_dispatch_matches_dense_fallback():
    """Sort-based capacity dispatch == dense all-experts path when capacity is
    unconstrained."""
    key = jax.random.PRNGKey(0)
    G, T, d, f, E, k = 2, 16, 8, 16, 4, 2
    params = moe_lib.init_moe(key, d, f, E, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (G, T, d))
    out_d, aux_d = moe_lib.moe_dense_fallback(params, x, num_experts=E, top_k=k)
    out_s, aux_s = moe_lib.moe_forward(params, x, num_experts=E, top_k=k, capacity_factor=float(E))
    np.testing.assert_allclose(np.asarray(out_s), np.asarray(out_d), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(float(aux_s), float(aux_d), rtol=1e-5)


def test_moe_capacity_drops_are_partial():
    key = jax.random.PRNGKey(0)
    G, T, d, f, E, k = 1, 32, 8, 16, 4, 2
    params = moe_lib.init_moe(key, d, f, E, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (G, T, d))
    out_tight, _ = moe_lib.moe_forward(params, x, num_experts=E, top_k=k, capacity_factor=0.5)
    out_loose, _ = moe_lib.moe_forward(params, x, num_experts=E, top_k=k, capacity_factor=float(E))
    # capacity drops change some token outputs but keep everything finite
    assert np.isfinite(np.asarray(out_tight)).all()
    assert not np.allclose(np.asarray(out_tight), np.asarray(out_loose))


def test_ssm_chunked_scan_matches_naive():
    B, T, C, N = 2, 37, 4, 8
    key = jax.random.PRNGKey(0)
    dA = jax.random.uniform(key, (B, T, C, N), minval=0.7, maxval=0.99)
    dBu = jax.random.normal(jax.random.PRNGKey(1), (B, T, C, N)) * 0.1
    h0 = jnp.zeros((B, C, N))
    hs, hT = ssm_lib._ssm_scan_chunked(dA, dBu, h0, chunk=8)
    # naive recurrence
    h = h0
    outs = []
    for t in range(T):
        h = dA[:, t] * h + dBu[:, t]
        outs.append(h)
    naive = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(hs), np.asarray(naive), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(naive[:, -1]), rtol=1e-4, atol=1e-5)


def test_mamba_decode_matches_forward():
    d, state, dt_rank = 16, 4, 2
    cfg_like = dict(d_inner=32, state=state, d_conv=4, dt_rank=dt_rank)
    key = jax.random.PRNGKey(0)
    params = ssm_lib.init_mamba(key, d, dtype=jnp.float32, **cfg_like)
    B, T = 2, 10
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, d)) * 0.5
    full = ssm_lib.mamba_forward(params, x, state=state, dt_rank=dt_rank, chunk=4)
    conv = jnp.zeros((B, 3, 32))
    ssm_state = jnp.zeros((B, 32, state))
    outs = []
    for t in range(T):
        o, conv, ssm_state = ssm_lib.mamba_decode(
            params, x[:, t : t + 1], conv, ssm_state, state=state, dt_rank=dt_rank
        )
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), rtol=2e-3, atol=2e-3)


def test_chunked_attention_matches_naive_softmax():
    B, S, H, KV, hd = 2, 33, 4, 2, 8
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, KV, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KV, hd))
    out = attention.chunked_attention(q, k, v, causal=True, chunk=8)
    # naive reference
    G = H // KV
    qf = q.reshape(B, S, KV, G, hd) / np.sqrt(hd)
    s = jnp.einsum("bqkgh,bskh->bqkgs", qf, k)
    mask = jnp.tril(jnp.ones((S, S), bool))
    s = jnp.where(mask[None, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    ref = jnp.einsum("bqkgs,bskh->bqkgh", p, v).reshape(B, S, H, hd)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-4)


def test_sliding_window_attention_masks_past():
    B, S, H, hd, W = 1, 16, 2, 4, 4
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, H, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, H, hd))
    out_w = attention.chunked_attention(q, k, v, causal=True, window=W, chunk=8)
    # last query must ignore keys before S-W: perturbing them changes nothing
    k2 = k.at[:, : S - W].set(jax.random.normal(jax.random.PRNGKey(3), (B, S - W, H, hd)))
    v2 = v.at[:, : S - W].set(jax.random.normal(jax.random.PRNGKey(4), (B, S - W, H, hd)))
    out_w2 = attention.chunked_attention(q, k2, v2, causal=True, window=W, chunk=8)
    np.testing.assert_allclose(
        np.asarray(out_w[:, -1]), np.asarray(out_w2[:, -1]), rtol=1e-5, atol=1e-5
    )


def test_rope_preserves_norm_and_relativity():
    S, H, hd = 8, 2, 16
    x = jax.random.normal(jax.random.PRNGKey(0), (1, S, H, hd))
    cos, sin = layers.rope_angles(jnp.arange(S), hd, 1e4)
    y = layers.apply_rope(x, cos[None], sin[None])
    np.testing.assert_allclose(
        np.asarray(jnp.linalg.norm(y, axis=-1)), np.asarray(jnp.linalg.norm(x, axis=-1)), rtol=1e-5
    )
    # relative property: <R_i q, R_j k> depends only on i-j
    q = jax.random.normal(jax.random.PRNGKey(1), (hd,))
    k = jax.random.normal(jax.random.PRNGKey(2), (hd,))

    def dot(i, j):
        ci, si = layers.rope_angles(jnp.arange(max(i, j) + 1), hd, 1e4)
        qr = layers.apply_rope(q[None, None, None, :], ci[None], si[None])[0, i % 1]  # dummy
        return qr

    c, s = layers.rope_angles(jnp.arange(10), hd, 1e4)
    qs = layers.apply_rope(jnp.broadcast_to(q, (1, 10, 1, hd)), c[None], s[None])
    ks = layers.apply_rope(jnp.broadcast_to(k, (1, 10, 1, hd)), c[None], s[None])
    d1 = float(jnp.vdot(qs[0, 5, 0], ks[0, 3, 0]))
    d2 = float(jnp.vdot(qs[0, 7, 0], ks[0, 5, 0]))
    assert abs(d1 - d2) < 1e-4


def test_layer_windows_gemma_pattern():
    cfg = get_config("gemma3-12b")
    w = np.asarray(lm.layer_windows(cfg))
    assert w.shape == (48,)
    assert (w[:5] == 1024).all() and w[5] == 0
    assert w.sum() == 1024 * 40


def test_chunked_ce_matches_full():
    B, S, d, V = 2, 17, 8, 32
    h = jax.random.normal(jax.random.PRNGKey(0), (B, S, d))
    w = jax.random.normal(jax.random.PRNGKey(1), (d, V))
    labels = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, V)
    mask = (jax.random.uniform(jax.random.PRNGKey(3), (B, S)) > 0.3).astype(jnp.float32)
    got = lm.chunked_ce_loss(h, w, labels, mask, chunk=5)
    logits = h @ w
    ref = layers.cross_entropy_loss(logits, labels, mask)
    np.testing.assert_allclose(float(got), float(ref), rtol=1e-5)
