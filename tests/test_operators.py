"""SketchOp layer: adjoint consistency, blocked streaming, batched application."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import operators as ops, sketches as sk, solve
from repro.utils import prng

N, D, M = 100, 7, 24  # N deliberately not a power of two / multiple of block sizes


def _op(kind, key, n=N, m=M):
    if kind == "hybrid":
        spec = sk.SketchSpec("hybrid", m, m_prime=min(2 * m, n), inner="sjlt", s=2)
    elif kind == "sjlt":
        spec = sk.SketchSpec(kind, m, s=3)
    elif kind == "uniform":
        spec = sk.SketchSpec(kind, m, replacement=False)
    else:
        spec = sk.SketchSpec(kind, m)
    scores = None
    if kind == "leverage":
        A = jax.random.normal(jax.random.PRNGKey(7), (n, 5))
        scores = sk.leverage_scores(A)
    return ops.make_operator(spec, key, n, scores=scores)


@pytest.mark.parametrize("kind", sk.KINDS)
def test_adjoint_consistency(kind):
    """⟨S x, y⟩ == ⟨x, Sᵀ y⟩ for every registered kind."""
    op = _op(kind, jax.random.PRNGKey(3))
    x = jax.random.normal(jax.random.PRNGKey(1), (N,))
    y = jax.random.normal(jax.random.PRNGKey(2), (M,))
    lhs = float(jnp.vdot(op.apply(x), y))
    rhs = float(jnp.vdot(x, op.adjoint(y)))
    assert abs(lhs - rhs) < 1e-3 * max(1.0, abs(lhs)), (kind, lhs, rhs)


@pytest.mark.parametrize("kind", sk.KINDS)
def test_adjoint_matches_materialized_transpose(kind):
    op = _op(kind, jax.random.PRNGKey(5))
    Y = jax.random.normal(jax.random.PRNGKey(4), (M, 3))
    St = np.asarray(op.materialize()).T
    np.testing.assert_allclose(
        np.asarray(op.adjoint(Y)), St @ np.asarray(Y), rtol=1e-4, atol=1e-4
    )


@pytest.mark.parametrize("kind", sk.KINDS)
@pytest.mark.parametrize("block_rows", [16, 33])
def test_apply_blocked_matches_apply(kind, block_rows):
    """Streaming over row tiles == one-shot, for block sizes that don't divide n."""
    op = _op(kind, jax.random.PRNGKey(11))
    A = jax.random.normal(jax.random.PRNGKey(0), (N, D))
    np.testing.assert_allclose(
        np.asarray(op.apply_blocked(A, block_rows=block_rows)),
        np.asarray(op.apply(A)),
        rtol=1e-4,
        atol=1e-4,
        err_msg=f"{kind} block_rows={block_rows}",
    )


def test_blocked_gaussian_bit_comparable():
    """Acceptance: blocked Gaussian reproduces unblocked at atol 1e-5 for n not
    divisible by the block size (tile (i,j) of S is a pure function of (key,i,j))."""
    n, d, m, block = 1000, 16, 64, 96  # 1000 % 96 != 0
    A = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    op = ops.make_operator(sk.SketchSpec("gaussian", m), jax.random.PRNGKey(1), n)
    got = np.asarray(op.apply_blocked(A, block_rows=block))
    want = np.asarray(op.apply(A))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("kind", ["gaussian", "srht", "sjlt", "uniform"])
def test_apply_batched_matches_loop(kind):
    """vmapped multi-worker application == a Python loop of per-key applies."""
    spec = sk.SketchSpec(kind, M, s=3)
    keys = jax.random.split(jax.random.PRNGKey(9), 5)
    A = jax.random.normal(jax.random.PRNGKey(0), (N, D))
    batched = ops.apply_batched(spec, keys, A)
    looped = jnp.stack([ops.apply(spec, keys[i], A) for i in range(5)])
    assert batched.shape == (5, M, D)
    np.testing.assert_allclose(
        np.asarray(batched), np.asarray(looped), rtol=1e-5, atol=1e-5
    )


def test_sketch_data_batched_shares_S_per_worker():
    n, d, q = 64, 5, 4
    A = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    b = jax.random.normal(jax.random.PRNGKey(1), (n,))
    spec = sk.SketchSpec("gaussian", M)
    keys = prng.worker_keys(jax.random.PRNGKey(2), q)
    SA, Sb = ops.sketch_data_batched(spec, keys, A, b)
    assert SA.shape == (q, M, d) and Sb.shape == (q, M)
    for w in range(q):
        SAw, Sbw = sk.sketch_data(spec, prng.worker_key(jax.random.PRNGKey(2), w), A, b)
        np.testing.assert_allclose(np.asarray(SA[w]), np.asarray(SAw), rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(Sb[w]), np.asarray(Sbw), rtol=1e-5, atol=1e-5)


def test_registry_dispatch_replaces_if_chain():
    """apply_sketch goes through the registry and matches the op's own apply."""
    A = jax.random.normal(jax.random.PRNGKey(0), (N, D))
    key = jax.random.PRNGKey(1)
    spec = sk.SketchSpec("sjlt", M, s=2)
    np.testing.assert_array_equal(
        np.asarray(sk.apply_sketch(spec, key, A)),
        np.asarray(ops.make_operator(spec, key, N).apply(A)),
    )
    with pytest.raises(ValueError, match="unknown sketch kind"):
        sk.SketchSpec("fourier", M)


def test_leverage_requires_scores():
    with pytest.raises(ValueError, match="data-dependent"):
        ops.make_operator(sk.SketchSpec("leverage", M), jax.random.PRNGKey(0), N)


def test_sketch_least_norm_uses_adjoint():
    """Right-sketch solver: x̂ = Sᵀẑ via op.adjoint matches the explicit-S formula."""
    n, d = 12, 64
    A = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    b = jax.random.normal(jax.random.PRNGKey(1), (n,))
    spec = sk.SketchSpec("gaussian", 4 * n)
    key = jax.random.PRNGKey(2)
    x = solve.sketch_least_norm(spec, key, A, b)
    S = np.asarray(ops.make_operator(spec, key, d).materialize())
    z = solve.least_norm(jnp.asarray(np.asarray(A) @ S.T), b)
    np.testing.assert_allclose(np.asarray(x), S.T @ np.asarray(z), rtol=1e-3, atol=1e-4)


def test_leverage_scores_approx_randomized_by_key():
    """Satellite fix: approx leverage scores must depend on the provided key."""
    A = jax.random.normal(jax.random.PRNGKey(0), (256, 6))
    s1 = sk.leverage_scores(A, method="approx", key=jax.random.PRNGKey(1))
    s2 = sk.leverage_scores(A, method="approx", key=jax.random.PRNGKey(2))
    assert not np.allclose(np.asarray(s1), np.asarray(s2))
    # still close to the exact scores regardless of key
    exact = sk.leverage_scores(A, method="qr")
    assert float(jnp.max(jnp.abs(s1 - exact))) < 0.5


def test_gaussian_op_matches_pallas_kernel_stream():
    """The jnp path and the RNG-fused Pallas kernel draw the same counter-based S."""
    n, d, m = 96, 17, 32
    A = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    key = jax.random.PRNGKey(5)
    spec_j = sk.SketchSpec("gaussian", m)
    spec_k = sk.SketchSpec("gaussian", m, use_kernel=True)
    np.testing.assert_allclose(
        np.asarray(sk.apply_sketch(spec_j, key, A)),
        np.asarray(sk.apply_sketch(spec_k, key, A)),
        rtol=1e-4,
        atol=1e-4,
    )


def test_trailing_dims_and_vectors():
    """Operators accept (n,), (n, d) and (n, d1, d2) inputs."""
    op = _op("gaussian", jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (N,))
    X3 = jax.random.normal(jax.random.PRNGKey(2), (N, 3, 2))
    assert op.apply(x).shape == (M,)
    assert op.apply(X3).shape == (M, 3, 2)
    assert op.adjoint(op.apply(x)).shape == (N,)
    np.testing.assert_allclose(
        np.asarray(op.apply(X3)[:, :, 0]),
        np.asarray(op.apply(X3[:, :, 0])),
        rtol=1e-5,
        atol=1e-5,
    )
