"""Sketched gradient compression: unbiasedness + error scaling."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gradcomp


def _tree(key, D=4096):
    k1, k2 = jax.random.split(key)
    return {"a": jax.random.normal(k1, (D,)), "b": jax.random.normal(k2, (D // 8, 8))}


def test_roundtrip_shapes_and_dtypes():
    g = _tree(jax.random.PRNGKey(0))
    cfg = gradcomp.GradCompressionConfig(enabled=True, ratio=0.25, kind="countsketch")
    payload, ctx = gradcomp.compress(cfg, jax.random.PRNGKey(1), g)
    rec = gradcomp.decompress(cfg, payload, ctx)
    assert jax.tree_util.tree_structure(rec) == jax.tree_util.tree_structure(g)
    for a, b in zip(jax.tree_util.tree_leaves(rec), jax.tree_util.tree_leaves(g)):
        assert a.shape == b.shape and a.dtype == b.dtype


def test_countsketch_unbiased():
    """E[Sᵀ S g] = g: average many independent sketches of the same gradient."""
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (512,))}
    cfg = gradcomp.GradCompressionConfig(enabled=True, ratio=0.25, kind="countsketch")

    def one(i):
        payload, ctx = gradcomp.compress(cfg, jax.random.fold_in(jax.random.PRNGKey(1), i), g)
        return gradcomp.decompress(cfg, payload, ctx)["w"]

    recs = jax.lax.map(one, jnp.arange(400), batch_size=50)
    mean = jnp.mean(recs, axis=0)
    rel = float(jnp.linalg.norm(mean - g["w"]) / jnp.linalg.norm(g["w"]))
    assert rel < 0.2, rel


def test_error_decreases_with_ratio():
    g = _tree(jax.random.PRNGKey(0))
    errs = []
    for ratio in (0.02, 0.1, 0.5):
        cfg = gradcomp.GradCompressionConfig(enabled=True, ratio=ratio, kind="countsketch")
        errs.append(float(gradcomp.compression_error(cfg, jax.random.PRNGKey(2), g)))
    assert errs[0] > errs[1] > errs[2]


def test_gaussian_projection_roundtrip():
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (256,))}
    cfg = gradcomp.GradCompressionConfig(enabled=True, ratio=0.5, kind="gaussian")
    err = float(gradcomp.compression_error(cfg, jax.random.PRNGKey(1), g))
    assert err < 1.5
