"""Multi-device SPMD tests — run in subprocesses so the 8 fake host devices never
leak into the main test process (jax locks device count at first init)."""
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.subprocess  # every test here shells out to a fresh mesh

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(body: str, devices: int = 8, timeout: int = 900) -> str:
    script = (
        "import os\n"
        f"os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count={devices}'\n"
        + textwrap.dedent(body)
    )
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True, timeout=timeout, env=env
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_distributed_sketch_solve_matches_local_average():
    _run(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import distributed, sketches as sk, solve, averaging
        from repro.utils import prng

        key = jax.random.PRNGKey(0)
        n, d, m = 2048, 16, 128
        A = jax.random.normal(key, (n, d))
        b = jax.random.normal(jax.random.PRNGKey(1), (n,))
        mesh = jax.make_mesh((8,), ("data",))
        spec = sk.SketchSpec("gaussian", m)
        xbar = distributed.distributed_sketch_solve(mesh, spec, key, A, b)
        # reference: same worker keys, computed locally
        xs = jnp.stack([
            solve.sketch_and_solve(spec, prng.worker_key(key, w, 0), A, b) for w in range(8)
        ])
        np.testing.assert_allclose(np.asarray(xbar), np.asarray(xs.mean(0)), rtol=1e-4, atol=1e-4)

        # straggler mask: drop workers 0-3 -> average of 4-7 only
        mask = jnp.array([0., 0., 0., 0., 1., 1., 1., 1.])
        xbar_m = distributed.distributed_sketch_solve(mesh, spec, key, A, b, straggler_mask=mask)
        np.testing.assert_allclose(np.asarray(xbar_m), np.asarray(xs[4:].mean(0)), rtol=1e-4, atol=1e-4)

        # master-sketch mode (batched apply, one pass over A) == worker-sketch mode
        xbar_ms = distributed.distributed_sketch_solve_master(mesh, spec, key, A, b)
        np.testing.assert_allclose(np.asarray(xbar_ms), np.asarray(xs.mean(0)), rtol=1e-4, atol=1e-4)
        print("DIST_OK")
        """
    )


def test_distributed_least_norm_and_multiround():
    _run(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import distributed, sketches as sk, solve
        key = jax.random.PRNGKey(0)
        n, d = 16, 256
        A = jax.random.normal(key, (n, d))
        b = jax.random.normal(jax.random.PRNGKey(1), (n,))
        mesh = jax.make_mesh((8,), ("data",))
        spec = sk.SketchSpec("gaussian", 4 * n)
        xbar = distributed.distributed_sketch_least_norm(mesh, spec, key, A, b)
        x_star = solve.least_norm(A, b)
        e1 = float(jnp.linalg.norm(xbar - x_star) / jnp.linalg.norm(x_star))
        assert e1 < 1.0, e1
        x2 = distributed.distributed_sketch_solve_multiround(
            mesh, sk.SketchSpec("gaussian", 128),
            key, jax.random.normal(key, (2048, 16)), jax.random.normal(key, (2048,)), rounds=3)
        assert np.isfinite(np.asarray(x2)).all()
        print("LN_OK")
        """
    )


def test_sketch_dp_training_step_runs():
    _run(
        """
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import get_config
        from repro.core import gradcomp
        from repro.data import lm_batch
        from repro.optim import AdamWConfig
        from repro.train.state import init_train_state
        from repro.train.sketch_dp import make_sketch_dp_step

        cfg = dataclasses.replace(get_config('granite-3-8b').reduced(),
                                  num_layers=2, d_model=32, d_ff=64, num_heads=2,
                                  num_kv_heads=1, head_dim=16, vocab_size=97)
        mesh = jax.make_mesh((8,), ("data",))
        comp = gradcomp.GradCompressionConfig(enabled=True, ratio=0.1, kind='countsketch')
        step = make_sketch_dp_step(cfg, AdamWConfig(lr=1e-3), mesh, comp=comp)
        state = init_train_state(cfg, AdamWConfig(lr=1e-3), jax.random.PRNGKey(0))
        batch = lm_batch(0, 0, batch=8, seq=32, vocab=cfg.vocab_size)
        mask = jnp.array([1.,1.,1.,0.,1.,1.,1.,1.])  # one straggler dropped
        with mesh:
            state, metrics = step(state, batch, jax.random.PRNGKey(1), mask)
        assert np.isfinite(float(metrics['loss']))
        assert int(state['step']) == 1
        # uncompressed + full mask variant
        step2 = make_sketch_dp_step(cfg, AdamWConfig(lr=1e-3), mesh, comp=None)
        with mesh:
            state2, m2 = step2(state, batch, jax.random.PRNGKey(2), jnp.ones((8,)))
        assert np.isfinite(float(m2['loss']))
        print("SKETCH_DP_OK")
        """
    )


def test_sharded_train_step_compiles_on_mini_mesh():
    """The production train step (GSPMD path) on a 2x2x2 pod/data/model mini-mesh."""
    _run(
        """
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.base import get_config
        from repro.data import lm_batch
        from repro.data.specs import batch_pspecs, input_specs
        from repro.distributed.sharding import ShardingRules
        from repro.optim import AdamWConfig
        from repro.train.state import init_train_state, train_state_pspecs
        from repro.train.step import make_train_step

        cfg = dataclasses.replace(get_config('granite-3-8b').reduced(),
                                  num_layers=2, d_model=32, d_ff=64, num_heads=4,
                                  num_kv_heads=2, head_dim=16, vocab_size=256)
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        rules = ShardingRules(dp=("pod", "data"), fsdp="data", tensor="model")
        opt = AdamWConfig(lr=1e-3)
        named = lambda tree: jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), tree, is_leaf=lambda x: isinstance(x, P))
        state_sh = named(train_state_pspecs(cfg, opt, rules))
        step = make_train_step(cfg, opt, rules=rules)
        state = init_train_state(cfg, opt, jax.random.PRNGKey(0))
        state = jax.device_put(state, state_sh)
        batch = lm_batch(0, 0, batch=8, seq=32, vocab=cfg.vocab_size)
        with mesh:
            jstep = jax.jit(step, in_shardings=(state_sh, None), out_shardings=(state_sh, None))
            state, metrics = jstep(state, batch)
        assert np.isfinite(float(metrics['loss']))
        # one more step to prove the state shardings round-trip
        with mesh:
            state, metrics = jstep(state, batch)
        assert int(state['step']) == 2
        print("GSPMD_OK")
        """
    )


def test_elastic_checkpoint_rescale():
    """Save on an 8-way mesh, restore onto a 4-way mesh (different dp width)."""
    _run(
        """
        import os, tempfile
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import save_checkpoint, restore_checkpoint

        mesh8 = jax.make_mesh((8,), ("data",))
        x = jnp.arange(64.0).reshape(8, 8)
        xs = jax.device_put(x, NamedSharding(mesh8, P("data", None)))
        d = tempfile.mkdtemp()
        save_checkpoint(d, 1, {"w": xs})

        mesh4 = jax.make_mesh((4, 2), ("data", "model"))
        sh = {"w": NamedSharding(mesh4, P("data", "model"))}
        r = restore_checkpoint(d, 1, jax.eval_shape(lambda: {"w": x}), shardings=sh)
        np.testing.assert_array_equal(np.asarray(r["w"]), np.asarray(x))
        assert r["w"].sharding == sh["w"]
        print("ELASTIC_OK")
        """
    )
