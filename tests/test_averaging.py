"""Master-side averaging + straggler machinery."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import averaging


def test_masked_average_plain_mean():
    xs = jax.random.normal(jax.random.PRNGKey(0), (8, 5))
    np.testing.assert_allclose(
        np.asarray(averaging.masked_average(xs)), np.asarray(jnp.mean(xs, 0)), rtol=1e-6
    )


def test_masked_average_subset():
    xs = jnp.stack([jnp.full((3,), float(i)) for i in range(4)])
    mask = jnp.array([1.0, 0.0, 0.0, 1.0])
    np.testing.assert_allclose(np.asarray(averaging.masked_average(xs, mask)), [1.5] * 3)


def test_masked_average_all_stragglers_poisons():
    """q' = 0 has no estimator: NaN by default, legacy x̄=0 only by explicit opt-in."""
    xs = jnp.ones((4, 3))
    out = averaging.masked_average(xs, jnp.zeros((4,)))
    assert np.isnan(np.asarray(out)).all()
    out0 = averaging.masked_average(xs, jnp.zeros((4,)), on_empty="zero")
    np.testing.assert_array_equal(np.asarray(out0), 0.0)
    # non-empty masks are untouched by the guard
    out1 = averaging.masked_average(xs, jnp.array([0.0, 1.0, 0.0, 0.0]))
    np.testing.assert_allclose(np.asarray(out1), 1.0)


def test_streaming_average_matches_batch():
    xs = jax.random.normal(jax.random.PRNGKey(0), (10, 4))
    st = averaging.StreamingAverage.init(4)
    for i in range(10):
        st = st.update(xs[i])
    np.testing.assert_allclose(
        np.asarray(st.mean), np.asarray(jnp.mean(xs, 0)), rtol=1e-5, atol=1e-7
    )
    assert int(st.count) == 10


def test_streaming_average_is_pytree():
    st = averaging.StreamingAverage.init(4)
    leaves = jax.tree_util.tree_leaves(st)
    assert len(leaves) == 2
    st2 = jax.jit(lambda s, x: s.update(x))(st, jnp.ones((4,)))
    assert float(st2.count) == 1.0


def test_straggler_mask_statistics():
    q = 1000
    mask = averaging.simulate_straggler_mask(jax.random.PRNGKey(0), q, drop_prob=0.2)
    frac = float(mask.mean())
    assert 0.7 < frac < 0.9
    mask2 = averaging.simulate_straggler_mask(
        jax.random.PRNGKey(1), q, drop_prob=0.0, deadline_quantile=0.5
    )
    assert abs(float(mask2.mean()) - 0.5) < 0.1


def test_psum_average_single_device_mesh():
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.utils.compat import shard_map

    mesh = jax.make_mesh((1,), ("data",))
    f = shard_map(
        lambda x, m: averaging.psum_average(x, m, "data"),
        mesh=mesh,
        in_specs=(P("data"), P("data")),
        out_specs=P("data"),
    )
    x = jnp.ones((1, 3))
    out = f(x, jnp.ones((1,)))
    np.testing.assert_allclose(np.asarray(out), np.ones((1, 3)))
