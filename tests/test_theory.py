"""Monte-Carlo validation of the paper's exact formulas and bounds (fast sizes)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sketches as sk, solve, theory
from repro.utils import prng


@pytest.fixture(scope="module")
def problem():
    n, d = 1024, 12
    key = jax.random.PRNGKey(0)
    A = jax.random.normal(key, (n, d))
    b = A @ jax.random.normal(jax.random.PRNGKey(1), (d,)) + jax.random.normal(
        jax.random.PRNGKey(2), (n,)
    )
    x_star = solve.lstsq(A, b)
    f_star = float(solve.residual_cost(A, b, x_star))
    return A, b, x_star, f_star


def _costs(A, b, spec, trials, key):
    def one(w):
        xk = solve.sketch_and_solve(spec, prng.worker_key(key, w), A, b)
        return solve.residual_cost(A, b, xk), xk

    return jax.lax.map(one, jnp.arange(trials), batch_size=64)


def test_lemma1_exact_error(problem):
    A, b, x_star, f_star = problem
    d = A.shape[1]
    m = 8 * d
    costs, _ = _costs(A, b, sk.SketchSpec("gaussian", m), 400, jax.random.PRNGKey(3))
    emp = float(jnp.mean(costs)) / f_star - 1.0
    exact = theory.gaussian_single_error(m, d)
    assert abs(emp - exact) / exact < 0.25, (emp, exact)


def test_theorem1_q_scaling(problem):
    """Averaged error must fall as 1/q (unbiased Gaussian sketch)."""
    A, b, x_star, f_star = problem
    d = A.shape[1]
    m = 8 * d
    spec = sk.SketchSpec("gaussian", m)
    key = jax.random.PRNGKey(4)
    _, xs = _costs(A, b, spec, 256, key)
    errs = {}
    for q in (1, 4, 16):
        groups = xs[: (256 // q) * q].reshape(256 // q, q, d)
        xbars = jnp.mean(groups, axis=1)
        costs = jax.vmap(lambda x: solve.residual_cost(A, b, x))(xbars)
        errs[q] = float(jnp.mean(costs)) / f_star - 1.0
        exact = theory.gaussian_averaged_error(m, d, q)
        assert abs(errs[q] - exact) / exact < 0.4, (q, errs[q], exact)
    assert errs[16] < errs[4] < errs[1]


def test_lemma2_decomposition(problem):
    """variance/q + bias²(q-1)/q must reproduce the measured averaged error for a
    *biased* sketch (uniform sampling)."""
    A, b, x_star, f_star = problem
    d = A.shape[1]
    m = 6 * d
    spec = sk.SketchSpec("uniform", m, replacement=True)
    key = jax.random.PRNGKey(5)
    _, xs = _costs(A, b, spec, 512, key)
    Axs = jax.vmap(lambda x: A @ x)(xs)
    var_term, bias_sq = theory.empirical_bias_variance(Axs, A @ x_star)
    q = 8
    pred = theory.lemma2_error(float(var_term), float(bias_sq), q)
    groups = xs[: (512 // q) * q].reshape(512 // q, q, d)
    costs = jax.vmap(lambda g: solve.residual_cost(A, b, jnp.mean(g, axis=0)))(groups)
    measured = float(jnp.mean(costs)) - f_star
    assert abs(measured - pred) / pred < 0.35, (measured, pred)


def test_lemma7_right_sketch():
    n, d = 16, 256
    key = jax.random.PRNGKey(0)
    A = jax.random.normal(key, (n, d))
    b = jax.random.normal(jax.random.PRNGKey(1), (n,))
    x_star = solve.least_norm(A, b)
    f_star = float(jnp.vdot(x_star, x_star))
    m = 6 * n
    spec = sk.SketchSpec("gaussian", m)

    def one(w):
        xk = solve.sketch_least_norm(spec, prng.worker_key(jax.random.PRNGKey(2), w), A, b)
        e = xk - x_star
        return jnp.vdot(e, e)

    errs = jax.lax.map(one, jnp.arange(300), batch_size=50)
    emp = float(jnp.mean(errs)) / f_star
    exact = theory.gaussian_least_norm_error(m, n, d)
    assert abs(emp - exact) / exact < 0.3, (emp, exact)


def test_right_sketch_average_improves():
    n, d = 16, 128
    A = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    b = jax.random.normal(jax.random.PRNGKey(1), (n,))
    x_star = solve.least_norm(A, b)
    spec = sk.SketchSpec("gaussian", 4 * n)
    xs = jax.vmap(
        lambda w: solve.sketch_least_norm(spec, prng.worker_key(jax.random.PRNGKey(2), w), A, b)
    )(jnp.arange(32))
    e1 = float(jnp.linalg.norm(xs[0] - x_star))
    e32 = float(jnp.linalg.norm(jnp.mean(xs, axis=0) - x_star))
    assert e32 < e1 / 2


def test_workers_for_error():
    assert theory.workers_for_error(m=200, d=20, eps=0.01) >= 10
    assert theory.workers_for_error(m=200, d=20, eps=1.0) >= 1


def test_success_probability_bounds():
    p = theory.theorem1_success_probability(m=400, d=20, q=10, eps=0.5)
    assert 0.0 <= p <= 1.0
    # more workers with same per-worker quality only multiplies the (1-e^-cm)^q term
    p_more_m = theory.theorem1_success_probability(m=800, d=20, q=10, eps=0.5)
    assert p_more_m >= p
