#!/usr/bin/env bash
# Tier-1 verification entry point.
#
# Forces 8 fake host devices so tests/test_multidevice.py exercises a real
# 8-device mesh on CPU (its subprocesses set the same flag for themselves; this
# makes the main process match, so mesh-building code paths see q > 1 too).
#
#   ./test.sh                 run the tier-1 pytest suite
#   ./test.sh --fast          inner-loop tier: reprolint gate, then deselect
#                             `slow` / `subprocess` marked tests (spawned
#                             pools, python -c meshes)
#   ./test.sh --lint          reprolint only: the AST contract checks
#                             (python -m repro.analysis src tests benchmarks)
#   ./test.sh --bench-smoke   run every benchmark at one tiny shape (kernel /
#                             perf-path regressions fail loudly here instead of
#                             only showing up in the JSON summaries)
set -euo pipefail
cd "$(dirname "$0")"

export XLA_FLAGS="${XLA_FLAGS:-} --xla_force_host_platform_device_count=8"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" == "--bench-smoke" ]]; then
    shift
    exec python -m benchmarks.run --smoke "$@"
fi

if [[ "${1:-}" == "--lint" ]]; then
    shift
    exec python -m repro.analysis "$@"
fi

if [[ "${1:-}" == "--fast" ]]; then
    shift
    # lint first: the AST gate is seconds and catches contract breaks before
    # the suite spends minutes compiling kernels (it also runs inside the
    # suite as tests/test_analysis_clean.py, so the full tier keeps the gate).
    python -m repro.analysis src tests benchmarks
    exec python -m pytest -x -q -m "not slow and not subprocess" "$@"
fi

exec python -m pytest -x -q "$@"
