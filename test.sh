#!/usr/bin/env bash
# Tier-1 verification entry point.
#
# Forces 8 fake host devices so tests/test_multidevice.py exercises a real
# 8-device mesh on CPU (its subprocesses set the same flag for themselves; this
# makes the main process match, so mesh-building code paths see q > 1 too).
#
#   ./test.sh                 run the tier-1 pytest suite
#   ./test.sh --fast          inner-loop tier: deselect `slow` / `subprocess`
#                             marked tests (spawned pools, python -c meshes)
#   ./test.sh --bench-smoke   run every benchmark at one tiny shape (kernel /
#                             perf-path regressions fail loudly here instead of
#                             only showing up in the JSON summaries)
set -euo pipefail
cd "$(dirname "$0")"

export XLA_FLAGS="${XLA_FLAGS:-} --xla_force_host_platform_device_count=8"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" == "--bench-smoke" ]]; then
    shift
    exec python -m benchmarks.run --smoke "$@"
fi

if [[ "${1:-}" == "--fast" ]]; then
    shift
    exec python -m pytest -x -q -m "not slow and not subprocess" "$@"
fi

exec python -m pytest -x -q "$@"
