#!/usr/bin/env bash
# Tier-1 verification entry point.
#
# Forces 8 fake host devices so tests/test_multidevice.py exercises a real
# 8-device mesh on CPU (its subprocesses set the same flag for themselves; this
# makes the main process match, so mesh-building code paths see q > 1 too).
set -euo pipefail
cd "$(dirname "$0")"

export XLA_FLAGS="${XLA_FLAGS:-} --xla_force_host_platform_device_count=8"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

exec python -m pytest -x -q "$@"
