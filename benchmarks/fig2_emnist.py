"""Paper Fig. 2: EMNIST-bymerge least squares — uniform sampling vs SJLT (s=20).

Synthetic class-template image data (47 classes, 784 dims) stands in for EMNIST
(offline container). One-hot-encoded multiclass least squares; we report cost and
test accuracy vs the number of averaged worker outputs, paper params q=100, m=2000,
s=20 (scaled in quick mode).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import sketches as sk, solve
from repro.data import emnist_like
from repro.data.regression import accuracy
from repro.utils import prng
from benchmarks.common import print_table, smoke, write_csv


def run(quick: bool = True):
    n_train, n_test = (30_000, 5_000) if quick else (200_000, 30_000)
    q = 20 if quick else 100
    m, s = 2000, 20
    if smoke():
        n_train, n_test, q, m = 3000, 500, 2, 1000
    key = jax.random.PRNGKey(0)
    A, B, meta = emnist_like(key, n_train)
    At, Bt, meta_t = emnist_like(jax.random.PRNGKey(1), n_test)

    X_star = solve.lstsq(A, B, reg=1e-3)
    f_star = float(solve.residual_cost(A, B, X_star))
    acc_star = float(accuracy(At, Bt, X_star, meta_t["labels"]))

    rows = []
    for name, spec in (
        ("uniform", sk.SketchSpec("uniform", m, replacement=False)),
        ("sjlt_s20", sk.SketchSpec("sjlt", m, s=s)),
    ):
        def worker(w):
            return solve.sketch_and_solve(spec, prng.worker_key(key, w), A, B.astype(A.dtype), reg=1e-3, method="chol")

        Xs = jax.lax.map(worker, jnp.arange(q), batch_size=4)  # (q, 784, 47)
        for k in (1, 5, 10, q):
            Xbar = jnp.mean(Xs[:k], axis=0)
            cost = float(solve.residual_cost(A, B, Xbar))
            acc = float(accuracy(At, Bt, Xbar, meta_t["labels"]))
            rows.append(
                {
                    "sketch": name, "avg_outputs": k,
                    "rel_err": (cost - f_star) / f_star,
                    "test_acc": acc, "exact_acc": acc_star,
                }
            )

    write_csv("fig2_emnist", rows)
    print_table("Fig.2 EMNIST-like: uniform vs SJLT", rows)
    # paper claim: SJLT drives cost lower / accuracy higher than uniform sampling
    return rows


if __name__ == "__main__":
    run(quick=True)
