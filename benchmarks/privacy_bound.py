"""Eq. (5) privacy accounting — including the paper's own airline evaluation.

The paper computes I(S_kA;A)/(nd) ≤ (m/n)·log(2πeγ²) = 1.17e-2 for the airline
matrix (γ=1, m=5e5, n=1.21e8). We reproduce that number exactly, sweep the bound in
m/n, and exercise the accountant's worst-case composition across workers.
"""
from __future__ import annotations

import math

from repro.core import privacy
from benchmarks.common import print_table, write_csv


def run(quick: bool = True):
    rows = []
    # the paper's exact evaluation
    v = privacy.mi_per_entry_bound(int(5e5), int(1.21e8), gamma=1.0)
    rows.append({"case": "paper_airline", "m": 5e5, "n": 1.21e8, "bound_nats": v,
                 "paper_value": 1.17e-2, "matches_paper": abs(v - 1.17e-2) < 2e-4})

    for ratio in (1e-4, 1e-3, 1e-2, 1e-1):
        n = int(1e8)
        m = int(ratio * n)
        rows.append({"case": f"ratio_{ratio:g}", "m": m, "n": n,
                     "bound_nats": privacy.mi_per_entry_bound(m, n),
                     "paper_value": float("nan"), "matches_paper": True})

    # composition across q workers (worst case additive) + the inversion helper
    acc = privacy.PrivacyAccountant()
    q, m, n = 100, 4000, int(2e6)
    for k in range(q):
        acc.record(m, n, tag=f"worker{k}")
    total = acc.total_per_entry_nats
    rows.append({"case": "q100_composition", "m": m, "n": n, "bound_nats": total,
                 "paper_value": float("nan"), "matches_paper": True})
    m_budget = privacy.sketch_dim_for_privacy(n, budget_nats_per_entry=0.01)
    rows.append({"case": "invert_budget_0.01", "m": m_budget, "n": n,
                 "bound_nats": privacy.mi_per_entry_bound(m_budget, n),
                 "paper_value": float("nan"), "matches_paper": True})

    write_csv("privacy_bound", rows)
    print_table("Eq.5 privacy bound", rows)
    return rows


if __name__ == "__main__":
    run(quick=True)
