"""Pallas kernel micro-bench: interpret-mode wall time (CPU) + structural roofline.

Wall times here are *interpret-mode* (Python-executed kernel bodies) — they validate
plumbing, not TPU speed. The meaningful numbers are the structural FLOP/byte terms
from each kernel's ``flops_and_bytes`` (the quantities the TPU roofline uses), and
the HBM-bytes saving of the RNG-fused Gaussian sketch vs a materialized S.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.fwht import ops as fwht_ops
from repro.kernels.gaussian import ops as g_ops
from repro.kernels.sjlt import ops as sjlt_ops
from repro.roofline.hw import V5E
from benchmarks.common import print_table, smoke, timeit, write_csv


def run(quick: bool = True):
    n, d, m, s = (2048, 128, 256, 4) if quick else (8192, 512, 1024, 4)
    if smoke():
        n, d, m = 512, 128, 128
    key = jax.random.PRNGKey(0)
    A = jax.random.normal(key, (n, d), jnp.float32)
    rows = []

    t = timeit(lambda: fwht_ops.fwht(A), repeat=2)
    fb = fwht_ops.flops_and_bytes(n, d)
    rows.append({
        "kernel": "fwht", "interp_ms": t * 1e3, "flops": fb["flops"], "bytes": fb["bytes"],
        "tpu_compute_us": fb["flops"] / V5E.peak_flops_bf16 * 1e6,
        "tpu_memory_us": fb["bytes"] / V5E.hbm_bw * 1e6,
    })

    buckets, signs = sjlt_ops.sjlt_params(key, n, s, m)
    t = timeit(lambda: sjlt_ops.sjlt_apply(A, buckets, signs, m), repeat=2)
    fb = sjlt_ops.flops_and_bytes(n, d, m, s)
    rows.append({
        "kernel": "sjlt", "interp_ms": t * 1e3, "flops": fb["flops"], "bytes": fb["bytes"],
        "tpu_compute_us": fb["flops"] / V5E.peak_flops_bf16 * 1e6,
        "tpu_memory_us": fb["bytes"] / V5E.hbm_bw * 1e6,
    })

    t = timeit(lambda: g_ops.gaussian_sketch(key, A, m), repeat=2)
    fb = g_ops.flops_and_bytes(n, d, m)
    rows.append({
        "kernel": "gaussian_rng_fused", "interp_ms": t * 1e3, "flops": fb["flops"], "bytes": fb["bytes"],
        "tpu_compute_us": fb["flops"] / V5E.peak_flops_bf16 * 1e6,
        "tpu_memory_us": fb["bytes"] / V5E.hbm_bw * 1e6,
    })
    rows.append({
        "kernel": "gaussian_materialized(ref)", "interp_ms": float("nan"),
        "flops": fb["flops"], "bytes": fb["bytes_materialized"],
        "tpu_compute_us": fb["flops"] / V5E.peak_flops_bf16 * 1e6,
        "tpu_memory_us": fb["bytes_materialized"] / V5E.hbm_bw * 1e6,
    })

    write_csv("kernel_bench", rows)
    print_table("Pallas kernels (interpret wall + structural roofline)", rows)
    return rows


if __name__ == "__main__":
    run(quick=True)
