"""Averaging (paper, one-shot, async) vs Iterative Hessian Sketch (ref. [11], sync).

The paper argues model averaging needs more total FLOPs but zero coordination:
q workers → error variance/q in ONE round, while IHS converges geometrically but
every iteration depends on the previous one (stragglers stall the chain). We put
both on the same axis: error vs number-of-worker-solves consumed.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import ihs, sketches as sk, solve
from repro.data import gaussian_regression
from repro.utils import prng
from benchmarks.common import print_table, smoke, write_csv


def run(quick: bool = True):
    n, d = (8192, 64) if quick else (65536, 256)
    if smoke():
        n, d = 1024, 16
    m = 8 * d
    key = jax.random.PRNGKey(0)
    A, b, _ = gaussian_regression(key, n, d, noise=0.5)
    x_star = solve.lstsq(A, b)
    f_star = float(solve.residual_cost(A, b, x_star))
    spec = sk.SketchSpec("gaussian", m)

    rows = []
    # averaging: error after k one-shot workers
    def worker(w):
        return solve.sketch_and_solve(spec, prng.worker_key(key, w), A, b)

    xs = jax.lax.map(worker, jnp.arange(16), batch_size=8)
    for k in (1, 2, 4, 8, 16):
        xbar = jnp.mean(xs[:k], axis=0)
        rows.append({
            "method": "averaging", "worker_solves": k,
            "rel_err": float(solve.relative_error(A, b, xbar, f_star)),
            "sync_rounds": 1,
        })
    # IHS: error after k sequential iterations
    trace = ihs.ihs_trace(spec, key, A, b, iters=8)
    for k in (1, 2, 4, 8):
        rows.append({
            "method": "ihs", "worker_solves": k,
            "rel_err": float(solve.relative_error(A, b, trace[k - 1], f_star)),
            "sync_rounds": k,
        })
    write_csv("ihs_baseline", rows)
    print_table("averaging (async, 1 round) vs IHS (sync, k rounds)", rows)
    return rows


if __name__ == "__main__":
    run(quick=True)
