"""Lemmas 4/5/6: empirical E‖z‖² and bias vs the paper's upper bounds.

For each sketch family we Monte-Carlo z = UᵀSᵀSb⊥ and the estimator bias
‖E[Ax̂]−Ax*‖, and check them against the closed-form bounds. n is a power of two so
the ROS (randomized Hadamard) sketch needs no padding, matching Lemma 4 exactly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import sketches as sk, solve, theory
from repro.utils import prng
from benchmarks.common import print_table, smoke, write_csv


def run(quick: bool = True):
    n, d = (2048, 16) if quick else (8192, 32)
    m = 16 * d
    trials = 300 if quick else 1000
    if smoke():
        n, d, trials = 512, 8, 16
    m = 16 * d
    key = jax.random.PRNGKey(3)
    A = jax.random.normal(key, (n, d))
    b = jax.random.normal(jax.random.PRNGKey(4), (n,))
    x_star = solve.lstsq(A, b)
    f_star = float(solve.residual_cost(A, b, x_star))
    b_perp = b - A @ x_star
    U, _, _ = jnp.linalg.svd(A, full_matrices=False)
    lev = jnp.sum(U * U, axis=1)
    min_lev, max_lev = float(jnp.min(lev)), float(jnp.max(lev))

    specs = {
        "ros": (sk.SketchSpec("srht", m), theory.ros_z_bound(m, d, f_star, min_lev)),
        "uniform_w": (
            sk.SketchSpec("uniform", m, replacement=True),
            theory.uniform_z_bound(m, n, f_star, max_lev, replacement=True),
        ),
        "uniform_wo": (
            sk.SketchSpec("uniform", m, replacement=False),
            theory.uniform_z_bound(m, n, f_star, max_lev, replacement=False),
        ),
        "leverage": (sk.SketchSpec("leverage", m), theory.leverage_z_bound(m, d, f_star)),
    }

    rows = []
    for name, (spec, z_bound) in specs.items():
        def one(w):
            wkey = prng.worker_key(key, w)
            SAb = sk.apply_sketch(spec, wkey, jnp.concatenate([U, b_perp[:, None], A, b[:, None]], axis=1))
            SU, Sbp = SAb[:, :d], SAb[:, d]
            SA, Sb = SAb[:, d + 1 : 2 * d + 1], SAb[:, -1]
            z = SU.T @ Sbp
            xk = solve.lstsq(SA, Sb)
            return jnp.vdot(z, z), A @ xk

        z2s, Axs = jax.lax.map(one, jnp.arange(trials), batch_size=32)
        emp_z2 = float(jnp.mean(z2s))
        bias = float(jnp.linalg.norm(jnp.mean(Axs, axis=0) - A @ x_star))
        # Lemma 3 bias bound needs the subspace-embedding ε for this (m, sketch)
        eps = float(
            theory.subspace_embedding_eps(U, sk.apply_sketch(spec, prng.worker_key(key, 10**6), U))
        )
        bias_bound = float(jnp.sqrt(4 * eps * max(z_bound, 1e-30)))
        rows.append(
            {
                "sketch": name, "m": m,
                "emp_z2": emp_z2, "z2_bound": z_bound, "z2_ok": emp_z2 <= z_bound * 1.05,
                "emp_bias": bias, "bias_bound": bias_bound, "eps": eps,
                "bias_ok": bias <= bias_bound * 1.05 + 1e-6,
            }
        )

    write_csv("bias_bounds", rows)
    print_table("Lemmas 4/5/6: empirical vs bounds", rows)
    return rows


if __name__ == "__main__":
    run(quick=True)
