"""Ablation: the paper's operator as a DP gradient compressor — convergence cost.

Trains the same tiny LM three ways for N steps (identical data/init/seeds):
  exact      — plain mean of the q per-worker gradients,
  sketched   — each step's mean gradient passes through CountSketch Sᵀ(S·ḡ)
               (E[SᵀS]=I → unbiased; m = ratio·D floats on the wire),
  straggler  — exact mean over a random 75% of workers per step (the paper's
               masked averaging applied to gradients).

The claim under test: unbiased sketch compression and straggler-masked averaging
cost a bounded amount of convergence at a 10× bandwidth saving — i.e. Algorithm 1's
variance/bias story (Lemma 2) transfers from solutions to gradients.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.core import gradcomp
from repro.data import lm_batch
from repro.optim import AdamWConfig, adamw_update, init_opt_state
from repro.train.step import make_loss_fn
from benchmarks.common import print_table, smoke, write_csv


def run(quick: bool = True):
    cfg = dataclasses.replace(
        get_config("granite-3-8b").reduced(), num_layers=2, d_model=32, d_ff=64,
        num_heads=2, num_kv_heads=1, head_dim=16, vocab_size=97,
    )
    steps = 30 if quick else 120
    if smoke():
        steps = 3
    q, B, S = 4, 8, 64
    opt_cfg = AdamWConfig(lr=3e-3)
    loss_fn = make_loss_fn(cfg)
    grad_fn = jax.jit(jax.value_and_grad(lambda p, b: loss_fn(p, b)[0]))
    comp = gradcomp.GradCompressionConfig(enabled=True, ratio=0.1, kind="countsketch")

    def worker_grads(params, step):
        """q per-worker (loss, grads) on disjoint batch shards."""
        outs = []
        for w in range(q):
            batch = lm_batch(0, step, batch=B // q, seq=S, vocab=cfg.vocab_size, row_offset=w * (B // q))
            outs.append(grad_fn(params, batch))
        return outs

    @jax.jit
    def update(params, opt, grads, lr_scale):
        return adamw_update(opt_cfg, params, grads, opt, lr_scale=lr_scale)

    def train(mode: str, seed: int = 0):
        from repro.models import lm as lm_mod

        params = lm_mod.init_params(cfg, jax.random.PRNGKey(seed))
        opt = init_opt_state(opt_cfg, params)
        losses = []
        key = jax.random.PRNGKey(123)
        for s in range(steps):
            outs = worker_grads(params, s)
            losses.append(float(sum(l for l, _ in outs) / q))
            gs = [g for _, g in outs]
            if mode == "straggler":
                kmask = jax.random.fold_in(key, s)
                mask = jax.random.bernoulli(kmask, 0.75, (q,))
                mask = mask.at[0].set(True)  # at least one worker reports
                gs = [g for i, g in enumerate(gs) if bool(mask[i])]
            mean = jax.tree_util.tree_map(lambda *xs: sum(xs) / len(xs), *gs)
            if mode == "sketched":
                payload, ctx = gradcomp.compress(comp, jax.random.fold_in(key, s), mean)
                mean = gradcomp.decompress(comp, payload, ctx)
            params, opt, _ = update(params, opt, mean, 1.0)
        return losses

    rows = []
    curves = {m: train(m) for m in ("exact", "sketched", "straggler")}
    for m, c in curves.items():
        rows.append(
            {
                "mode": m,
                "loss_start": c[0],
                "loss_mid": c[len(c) // 2],
                "loss_final": c[-1],
                "final_gap_vs_exact": c[-1] - curves["exact"][-1],
                "wire_fraction": 0.1 if m == "sketched" else 1.0,
            }
        )
    write_csv("sketch_dp_ablation", rows)
    print_table("sketch-DP ablation: gradient compression / straggler masking", rows)
    return rows


if __name__ == "__main__":
    run(quick=True)
