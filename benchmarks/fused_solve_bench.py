"""Fused single-pass sketch→Gram solve vs the two-pass reference, plus the
mesh-vs-loop dispatch of multi-worker batching.

Writes ``results/bench/BENCH_fused_solve.json`` with op/backend/shape, ms and
effective GB/s so the perf trajectory is tracked across PRs. Two claims:

  1. ``sketch_and_solve(method="fused")`` — one streamed pass over [A | b]
     accumulating (G, c), then a d×d Cholesky — beats the two-pass reference
     (materialize (SA, Sb), then QR) at the large-n shape. The headline row is
     the SJLT, where the sketch pass is cheap enough that the avoided SA
     materialization and the QR→Cholesky tail dominate.
  2. ``apply_batched`` dispatch: the shard_map-over-mesh path is only taken when
     the mesh has real devices to shard over (``operators._mesh_batch_enabled``);
     on forced host devices the auto path falls back to the loop, so batched
     dispatch is never slower than the loop fallback. Both forced-mesh and auto
     timings are recorded for SRHT.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sketches as sk, solve
from benchmarks.common import RESULTS_DIR, block, print_table, smoke as _smoke, write_csv
from repro.analysis.annotations import sanctioned_wall_timer

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@sanctioned_wall_timer
def _time_pair(fn_a, fn_b, repeat: int = 7):
    """Interleaved min-of-``repeat`` wall seconds for two thunks (after warmup)."""
    block(fn_a())
    block(fn_b())
    ta, tb = [], []
    for _ in range(repeat):
        t0 = time.perf_counter()
        block(fn_a())
        ta.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        block(fn_b())
        tb.append(time.perf_counter() - t0)
    return min(ta), min(tb)


def _shapes(quick: bool):
    """(label, spec_builder, n, d, m, headline)."""
    if _smoke():
        return [
            ("sjlt_s4", lambda m: sk.SketchSpec("sjlt", m, s=4), 2048, 32, 128, True),
            ("gaussian", lambda m: sk.SketchSpec("gaussian", m), 2048, 32, 128, False),
            ("srht", lambda m: sk.SketchSpec("srht", m), 2048, 32, 128, False),
        ]
    n_big = 65536 if quick else 262144
    return [
        # headline large-n shape: sparse sketch, fat head — the regime the fused
        # path targets (sketch pass cheap, SA materialization + QR tail visible)
        ("sjlt_s4", lambda m: sk.SketchSpec("sjlt", m, s=4), n_big * 2 if quick else n_big, 256, 1024, True),
        ("gaussian", lambda m: sk.SketchSpec("gaussian", m), n_big, 32, 256, False),
        ("srht", lambda m: sk.SketchSpec("srht", m), n_big, 64, 512, False),
    ]


def _bench_mesh_srht(quick: bool) -> dict:
    """Forced-mesh vs loop apply_batched for SRHT, on 8 fake host devices (subprocess
    so the device count never leaks into this process)."""
    n = 2048 if _smoke() else 65536
    script = textwrap.dedent(
        f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json, time
        import jax, jax.numpy as jnp
        from repro.core import operators as ops, sketches as sk
        from repro.utils import prng

        n, d, m, q = {n}, 64, 512, 8
        A = jax.random.normal(jax.random.PRNGKey(0), (n, d), jnp.float32)
        keys = prng.worker_keys(jax.random.PRNGKey(1), q)
        mesh = jax.make_mesh((8,), ("workers",))
        spec = sk.SketchSpec("srht", m)

        def timeit(fn, repeat=5):
            jax.block_until_ready(fn())
            ts = []
            for _ in range(repeat):
                t0 = time.perf_counter()
                jax.block_until_ready(fn())
                ts.append(time.perf_counter() - t0)
            return min(ts)

        os.environ["REPRO_MESH_BATCH"] = "1"
        t_mesh = timeit(jax.jit(lambda: ops.apply_batched(spec, keys, A, mesh=mesh, axis_names=("workers",))))
        os.environ["REPRO_MESH_BATCH"] = "0"
        t_auto = timeit(jax.jit(lambda: ops.apply_batched(spec, keys, A, mesh=mesh, axis_names=("workers",))))
        t_loop = timeit(jax.jit(lambda: ops.apply_batched(spec, keys, A)))
        print(json.dumps({{"n": n, "d": d, "m": m, "q": q,
                           "mesh_forced_s": t_mesh, "auto_s": t_auto, "loop_s": t_loop}}))
        """
    )
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True, timeout=900, env=env
    )
    if out.returncode != 0:
        print(f"WARN: mesh-vs-loop subprocess failed:\n{out.stderr[-2000:]}")
        return {"error": "subprocess failed"}
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    rec["auto_vs_loop"] = rec["loop_s"] / rec["auto_s"]
    rec["auto_no_slower_than_loop"] = bool(rec["auto_s"] <= rec["loop_s"] * 1.1)
    rec["mesh_forced_vs_loop"] = rec["loop_s"] / rec["mesh_forced_s"]
    return rec


def run(quick: bool = True):
    repeat = 3 if _smoke() else 7
    rows = []
    summary = {"backend": jax.default_backend(), "shapes": {}}

    for i, (label, mk_spec, n, d, m, headline) in enumerate(_shapes(quick)):
        spec = mk_spec(m)
        key = jax.random.fold_in(jax.random.PRNGKey(0), i)
        A = jax.random.normal(key, (n, d), jnp.float32)
        b = jax.random.normal(jax.random.PRNGKey(1), (n,), jnp.float32)
        fused = jax.jit(lambda k, A, b, spec=spec: solve.sketch_and_solve(spec, k, A, b))
        twopass = jax.jit(
            lambda k, A, b, spec=spec: solve.sketch_and_solve(spec, k, A, b, method="qr")
        )
        t_fused, t_two = _time_pair(
            lambda: fused(key, A, b), lambda: twopass(key, A, b), repeat=repeat
        )
        # solutions agree to fp32 tolerance (same S under the same key)
        x_f, x_q = fused(key, A, b), twopass(key, A, b)
        err = float(jnp.max(jnp.abs(x_f - x_q)) / jnp.maximum(jnp.max(jnp.abs(x_q)), 1e-30))
        bytes_pass = 4 * n * (d + 1)  # one streamed read of [A | b]
        row = {
            "op": label,
            "backend": summary["backend"],
            "n": n,
            "d": d,
            "m": m,
            "fused_ms": t_fused * 1e3,
            "twopass_ms": t_two * 1e3,
            "speedup": t_two / t_fused,
            "fused_gbps": bytes_pass / t_fused / 1e9,
            "rel_err": err,
            "headline": headline,
        }
        rows.append(row)
        summary["shapes"][label] = row
        if headline:
            summary["headline"] = row

    summary["mesh_apply_batched_srht"] = _bench_mesh_srht(quick)

    write_csv("fused_solve_bench", rows)
    os.makedirs(RESULTS_DIR, exist_ok=True)
    json_path = os.path.join(RESULTS_DIR, "BENCH_fused_solve.json")
    with open(json_path, "w") as f:
        json.dump(summary, f, indent=2)
    print_table("fused single-pass solve vs two-pass (materialize SA + QR)", rows)
    print(f"JSON summary: {json_path}")

    h = summary.get("headline", {})
    if _smoke():
        print("SMOKE: shapes are tiny; speedup numbers not meaningful")
    elif h.get("speedup", 0.0) >= 1.5:
        print(
            f"PASS: fused solve {h['speedup']:.2f}x over materialize-then-Gram at "
            f"n={h['n']} d={h['d']} m={h['m']} ({h['op']})"
        )
    else:
        print(
            f"WARN: fused headline speedup {h.get('speedup', 0.0):.2f}x < 1.5x on this "
            f"host — see {json_path}"
        )
    mesh = summary["mesh_apply_batched_srht"]
    if mesh.get("auto_no_slower_than_loop"):
        print(
            f"PASS: batched SRHT auto-dispatch no slower than loop "
            f"(auto {mesh['auto_s']*1e3:.1f}ms vs loop {mesh['loop_s']*1e3:.1f}ms; "
            f"forced mesh on fake devices: {mesh['mesh_forced_s']*1e3:.1f}ms)"
        )
    elif "error" not in mesh:
        print(f"WARN: batched SRHT auto path slower than loop — see {json_path}")
    return rows
