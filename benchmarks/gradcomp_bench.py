"""Beyond-paper: sketched gradient compression — error vs bandwidth saving.

The paper's E[SᵀS]=I operator as a DP all-reduce compressor (see core/gradcomp.py).
Reports reconstruction error and wire-bytes ratio per compression ratio, plus the
variance reduction from averaging q workers' fresh sketches (Lemma-2 logic applied
to gradients)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import gradcomp
from benchmarks.common import print_table, smoke, write_csv


def run(quick: bool = True):
    D = 1 << 16 if quick else 1 << 20
    if smoke():
        D = 1 << 12
    key = jax.random.PRNGKey(0)
    g = {"w": jax.random.normal(key, (D,)), "b": jax.random.normal(jax.random.PRNGKey(1), (D // 16,))}
    rows = []
    for ratio in (0.01, 0.05, 0.1, 0.25):
        for kind in ("countsketch", "gaussian"):
            if kind == "gaussian" and ratio * D > 4096:
                continue  # m×D dense S too big on CPU
            cfg = gradcomp.GradCompressionConfig(enabled=True, ratio=ratio, kind=kind)
            err = float(gradcomp.compression_error(cfg, key, g))
            rows.append({"kind": kind, "ratio": ratio, "rel_err": err, "wire_fraction": ratio})
    # q-averaging of fresh sketches: variance ∝ 1/q (Lemma 2 on gradients)
    cfg = gradcomp.GradCompressionConfig(enabled=True, ratio=0.05, kind="countsketch")
    base = None
    for q in (1, 4, 16):
        recs = []
        for w in range(q):
            payload, ctx = gradcomp.compress(cfg, jax.random.fold_in(key, w), g)
            recs.append(gradcomp.decompress(cfg, payload, ctx))
        mean = jax.tree_util.tree_map(lambda *xs: sum(xs) / q, *recs)
        num = jnp.sqrt(sum(jnp.sum((a - b) ** 2) for a, b in zip(jax.tree_util.tree_leaves(mean), jax.tree_util.tree_leaves(g))))
        den = jnp.sqrt(sum(jnp.sum(b ** 2) for b in jax.tree_util.tree_leaves(g)))
        err = float(num / den)
        base = base or err
        rows.append({"kind": "countsketch_qavg", "ratio": 0.05 * q, "rel_err": err,
                     "wire_fraction": 0.05})
    write_csv("gradcomp", rows)
    print_table("sketched gradient compression", rows)
    return rows


if __name__ == "__main__":
    run(quick=True)
