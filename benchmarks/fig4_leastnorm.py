"""Paper Fig. 4: right-sketch least-norm averaging (n < d).

Plot (a) is reproduced at the paper's EXACT dimensions: n=50, d=1000, m=200, m'=500 —
Gaussian vs uniform vs hybrid(sampling→Gaussian). Plot (b)'s airline-with-pairwise-
interactions design is regenerated synthetically at n=2000, d≈11k (quick: scaled).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import averaging, sketches as sk, solve
from repro.utils import prng
from benchmarks.common import print_table, smoke, write_csv


def _least_norm_curve(A, b, specs, q, key, rows, tag):
    x_star = solve.least_norm(A, b)
    f_star = float(jnp.vdot(x_star, x_star))
    for name, spec in specs.items():
        def worker(w):
            return solve.sketch_least_norm(spec, prng.worker_key(key, w), A, b)

        xs = jax.lax.map(worker, jnp.arange(q), batch_size=8)
        for k in (1, 5, 20, q):
            xbar = jnp.mean(xs[:k], axis=0)
            # approximation error for least-norm: ||xbar - x*||^2 / ||x*||^2
            e = xbar - x_star
            rows.append(
                {
                    "dataset": tag, "sketch": name, "avg_outputs": k,
                    "rel_err": float(jnp.vdot(e, e) / f_star),
                }
            )


def run(quick: bool = True):
    rows = []
    key = jax.random.PRNGKey(0)

    # plot (a): exact paper dims. q reaches past the uniform sketch's bias floor —
    # the separation gaussian < hybrid < uniform only shows once variance/q drops
    # below the bias² term (Lemma 2).
    n, d, m, m_prime = 50, 1000, 200, 500
    A = jax.random.normal(key, (n, d))
    b = jax.random.normal(jax.random.PRNGKey(1), (n,))
    q = 50 if quick else 100
    if smoke():
        q = 4
    specs = {
        "gaussian": sk.SketchSpec("gaussian", m),
        "uniform": sk.SketchSpec("uniform", m, replacement=False),
        "hybrid_gauss": sk.SketchSpec("hybrid", m, m_prime=m_prime, inner="gaussian"),
    }
    _least_norm_curve(A, b, specs, q, key, rows, "fig4a_n50_d1000")

    # plot (b): airline-like with pairwise interactions (underdetermined)
    n2 = 400 if quick else 2000
    base_d = 24 if quick else 107
    if smoke():
        n2, base_d = 100, 12
    kb = jax.random.PRNGKey(2)
    X = (jax.random.uniform(kb, (n2, base_d)) < 0.15).astype(jnp.float32)
    inter = jnp.einsum("ni,nj->nij", X, X).reshape(n2, base_d * base_d)
    A2 = jnp.concatenate([X, inter], axis=1)
    keep = jnp.sum(jnp.abs(A2), axis=0) > 0
    A2 = A2[:, keep]
    # binary interaction rows can be rank-deficient (duplicate/empty rows) → AAᵀ
    # singular; a small dense perturbation restores full row rank (the real airline
    # matrix has numeric columns playing this role)
    A2 = A2 + 0.01 * jax.random.normal(jax.random.PRNGKey(7), A2.shape)
    b2 = jax.random.normal(jax.random.PRNGKey(3), (n2,))
    d2 = A2.shape[1]
    # right-sketch regime needs n2 < m2 < m' <= d2 (paper: n=2000, m=4000, m'=8000, d=11406)
    m2 = min(2 * n2, (n2 + d2) // 2)
    mp2 = min(4 * n2, d2)
    specs2 = {
        "gaussian": sk.SketchSpec("gaussian", m2),
        "uniform": sk.SketchSpec("uniform", m2, replacement=False),
        "hybrid_gauss": sk.SketchSpec("hybrid", m2, m_prime=mp2, inner="gaussian"),
    }
    _least_norm_curve(A2, b2, specs2, q, key, rows, f"fig4b_interactions_d{d2}")

    write_csv("fig4_leastnorm", rows)
    print_table("Fig.4 least-norm averaging", rows)
    return rows


if __name__ == "__main__":
    run(quick=True)
