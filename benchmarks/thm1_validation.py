"""Theorem 1 / Lemma 1 / Lemma 7 validation: Monte-Carlo vs the paper's EXACT errors.

This is the experiment the paper itself could not run (it only has expectations):
many-trial empirical means of (f(x̂)−f*)/f* and (f(x̄)−f*)/f* against

    Lemma 1  :  d/(m−d−1)            (single Gaussian sketch)
    Theorem 1:  d/(q·(m−d−1))        (q-average)
    Lemma 7  :  (d−n)/(m−n−1)        (right sketch, n<d)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sketches as sk, solve, theory
from repro.data import gaussian_regression
from repro.utils import prng
from benchmarks.common import print_table, smoke, write_csv


def run(quick: bool = True):
    n, d = (2048, 24) if quick else (8192, 48)
    trials = 200 if quick else 600
    if smoke():
        n, d, trials = 512, 8, 16
    key = jax.random.PRNGKey(7)
    A, b, _ = gaussian_regression(key, n, d, noise=1.0, planted=True)
    x_star = solve.lstsq(A, b)
    f_star = float(solve.residual_cost(A, b, x_star))

    rows = []
    for m in ([4 * d, 8 * d] if quick else [2 * d + 4, 4 * d, 8 * d]):
        spec = sk.SketchSpec("gaussian", m)

        def one(widx):
            xk = solve.sketch_and_solve(spec, prng.worker_key(key, widx), A, b)
            return solve.residual_cost(A, b, xk)

        costs = jax.lax.map(one, jnp.arange(trials), batch_size=32)
        emp_single = float(jnp.mean(costs)) / f_star - 1.0
        exact_single = theory.gaussian_single_error(m, d)
        rows.append(
            {
                "claim": "Lemma1", "m": m, "q": 1,
                "empirical": emp_single, "exact": exact_single,
                "ratio": emp_single / exact_single,
            }
        )
        for q in (4, 16):
            n_groups = trials // q

            def xbar_cost(g):
                def xk(w):
                    return solve.sketch_and_solve(spec, prng.worker_key(key, g * q + w), A, b)

                xs = jax.lax.map(xk, jnp.arange(q), batch_size=8)
                return solve.residual_cost(A, b, jnp.mean(xs, axis=0))

            costs_q = jax.lax.map(xbar_cost, jnp.arange(n_groups))
            emp_avg = float(jnp.mean(costs_q)) / f_star - 1.0
            exact_avg = theory.gaussian_averaged_error(m, d, q)
            rows.append(
                {
                    "claim": "Thm1", "m": m, "q": q,
                    "empirical": emp_avg, "exact": exact_avg,
                    "ratio": emp_avg / exact_avg,
                }
            )

    # Lemma 7 (right sketch): n < d
    n2, d2 = (24, 512) if quick else (48, 1024)
    if smoke():
        n2, d2 = 12, 128
    A2, b2, _ = gaussian_regression(jax.random.PRNGKey(8), n2, d2, noise=0.0, planted=False)
    x_star2 = solve.least_norm(A2, b2)
    f_star2 = float(jnp.vdot(x_star2, x_star2))
    m2 = 4 * n2
    spec2 = sk.SketchSpec("gaussian", m2)

    def one_ln(widx):
        xk = solve.sketch_least_norm(spec2, prng.worker_key(key, widx), A2, b2)
        e = xk - x_star2
        return jnp.vdot(e, e)

    errs = jax.lax.map(one_ln, jnp.arange(trials), batch_size=32)
    emp7 = float(jnp.mean(errs)) / f_star2
    exact7 = theory.gaussian_least_norm_error(m2, n2, d2)
    rows.append({"claim": "Lemma7", "m": m2, "q": 1, "empirical": emp7, "exact": exact7, "ratio": emp7 / exact7})

    write_csv("thm1_validation", rows)
    print_table("Theorem 1 / Lemma 1 / Lemma 7: empirical vs exact", rows)
    return rows


if __name__ == "__main__":
    run(quick=True)
