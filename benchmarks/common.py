"""Shared benchmark plumbing: timing, CSV output, worker-runtime simulation."""
from __future__ import annotations

import csv
import os
import time
from typing import Dict, List

import jax
import numpy as np

from repro.analysis.annotations import sanctioned_wall_timer
from repro.utils import env as envcfg

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "bench")


def smoke() -> bool:
    """True under `benchmarks.run --smoke` / `test.sh --bench-smoke`: every module
    shrinks to one tiny shape so the whole sweep finishes in CI time."""
    return bool(envcfg.read_bool("REPRO_BENCH_SMOKE", False))


def block(x):
    return jax.block_until_ready(x)


@sanctioned_wall_timer
def timeit(fn, *args, repeat: int = 3):
    """Median wall seconds of fn(*args) after one warmup."""
    block(fn(*args))
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        block(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def write_csv(name: str, rows: List[Dict]) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name + ".csv")
    if rows:
        keys = list(rows[0].keys())
        with open(path, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=keys)
            w.writeheader()
            w.writerows(rows)
    return path


def print_table(title: str, rows: List[Dict]) -> None:
    print(f"\n== {title} ==")
    if not rows:
        print("(no rows)")
        return
    keys = list(rows[0].keys())
    print(" | ".join(keys))
    for r in rows:
        print(" | ".join(f"{r[k]:.4g}" if isinstance(r[k], float) else str(r[k]) for k in keys))


def simulate_worker_times(key, q: int, *, mean_s: float, sigma: float = 0.35) -> np.ndarray:
    """Lognormal worker runtimes — the paper's AWS-Lambda latency profile (Fig. 1
    captions report 1.2-1.5x spread between sketch types; stragglers in the tail)."""
    z = jax.random.normal(key, (q,))
    return np.asarray(mean_s * np.exp(sigma * np.asarray(z)))
