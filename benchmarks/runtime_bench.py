"""Async runtime engine: error-vs-wallclock and effective q′ under each LatencyModel.

Writes ``results/bench/BENCH_runtime.json`` (plus a CSV row per model) recording,
for one synthetic regression problem:

  1. the error-vs-simulated-wallclock trace of the streaming average (the paper's
     Fig. 1 x-axis, with the latency distribution injected instead of measured),
     the realized q′, retry/timeout counts and latency percentiles per model;
  2. the early-stopping claim: under the straggler-heavy (heavy-tail) model with a
     configured ``target_error``, the master halts with the target met while a
     demonstrable fraction of tasks is still outstanding (``stopped_early`` +
     ``completed < submitted`` in the JSON);
  3. determinism: the same seed replays the identical event log (hash recorded).

Smoke mode (``benchmarks.run --smoke`` / ``test.sh --bench-smoke``) shrinks the
problem so the whole sweep is CI-sized.
"""
from __future__ import annotations

import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import RESULTS_DIR, print_table, smoke, write_csv
from repro import runtime as rt
from repro.core import sketches as sk, solve


def _problem(n, d):
    key = jax.random.PRNGKey(0)
    A = jax.random.normal(key, (n, d))
    x_true = jax.random.normal(jax.random.PRNGKey(1), (d,))
    b = A @ x_true + 0.5 * jax.random.normal(jax.random.PRNGKey(2), (n,))
    x_star = solve.lstsq(A, b)
    f_star = float(solve.residual_cost(A, b, x_star))
    return key, A, b, f_star


def _true_error_fn(A, b, f_star):
    """(f(x̄) − f*)/f* on the *full* problem — the benchmark knows the truth."""

    @jax.jit
    def _cost(x):
        return solve.residual_cost(A, b, x)

    def err(xbar, _count):
        return (float(_cost(jnp.asarray(xbar, A.dtype))) - f_star) / f_star

    return err


def _models(seed: int):
    return {
        "lognormal": rt.LognormalLatency(seed=seed, mean_s=1.0, sigma=0.35),
        "heavytail": rt.HeavyTailLatency(seed=seed, scale_s=0.7, alpha=1.3),
        "harddrop": rt.DropLatency(
            seed=seed,
            inner=rt.LognormalLatency(seed=seed, mean_s=1.0, sigma=0.35),
            drop_prob=0.25,
        ),
    }


def run(quick: bool = True):
    if smoke():
        n, d, m, q = 1024, 16, 128, 8
    else:
        n, d, m, q = (16384, 64, 512, 32) if quick else (65536, 128, 1024, 64)
    key, A, b, f_star = _problem(n, d)
    spec = sk.SketchSpec("gaussian", m)
    err_fn = _true_error_fn(A, b, f_star)
    cfg = rt.RuntimeConfig(deadline_s=2.0, max_retries=2, backoff_base_s=0.1)

    rows, traces = [], {}
    for name, model in _models(seed=5).items():
        res = rt.serverless_sketch_solve(
            spec, key, A, b, q=q, latency=model, config=cfg, error_fn=err_fn
        )
        # determinism: replay and hash both event logs
        res2 = rt.serverless_sketch_solve(
            spec, key, A, b, q=q, latency=model, config=cfg, error_fn=err_fn
        )
        log_a = "\n".join(res.events.lines())
        log_b = "\n".join(res2.events.lines())
        s = res.summary(deadline=cfg.deadline_s)
        rows.append(
            {
                "model": name,
                "q": q,
                "effective_q": s["effective_q"],
                "retries": s["retries"],
                "timeouts": s["timeouts"],
                "p50_latency_s": s.get("p50_latency_s", float("nan")),
                "p95_latency_s": s.get("p95_latency_s", float("nan")),
                "sim_makespan_s": s["sim_makespan_s"],
                "final_rel_err": res.final_error,
                "replay_identical": log_a == log_b,
            }
        )
        traces[name] = {
            "error_trace": [
                {"t": t, "count": c, "rel_err": e} for t, c, e in res.events.error_trace()
            ],
            "summary": s,
            "event_log_sha256": hashlib.sha256(log_a.encode()).hexdigest(),
        }

    # ---- early stopping under the straggler-heavy config: the master halts with
    # the target met while tasks are still outstanding (never waits for the tail)
    single = d / (m - d - 1)  # Lemma 1
    target = single / max(2, q // 4)  # reachable well before all q arrive
    es_cfg = rt.RuntimeConfig(
        deadline_s=4.0, max_retries=2, backoff_base_s=0.1, target_error=target,
        min_results=2,
    )
    es = rt.serverless_sketch_solve(
        spec, key, A, b, q=q, latency=_models(seed=5)["heavytail"], config=es_cfg,
        error_fn=err_fn,
    )
    early = {
        "latency_model": "heavytail",
        "target_error": target,
        "final_error": es.final_error,
        "stopped_early": es.stopped_early,
        "submitted": es.submitted,
        "completed": es.count,
        "cancelled": es.events.counts().get("cancel", 0),
        "sim_makespan_s": es.summary()["sim_makespan_s"],
        "within_target": (es.final_error is not None and es.final_error <= target),
    }

    summary = {
        "backend": jax.default_backend(),
        "problem": {"n": n, "d": d, "m": m, "q": q, "kind": spec.kind},
        "deadline_s": cfg.deadline_s,
        "models": traces,
        "rows": rows,
        "early_stop": early,
    }
    write_csv("runtime_bench", rows)
    os.makedirs(RESULTS_DIR, exist_ok=True)
    json_path = os.path.join(RESULTS_DIR, "BENCH_runtime.json")
    with open(json_path, "w") as f:
        json.dump(summary, f, indent=2)
    print_table("async runtime: effective q' / retries / error under latency models", rows)
    print(f"JSON summary: {json_path}")

    ok_replay = all(r["replay_identical"] for r in rows)
    print(("PASS" if ok_replay else "FAIL") + ": deterministic replay (same seed ⇒ same event log)")
    if early["stopped_early"] and early["within_target"] and early["completed"] < early["submitted"]:
        print(
            f"PASS: early stop at q'={early['completed']}/{early['submitted']} "
            f"(rel_err {early['final_error']:.4g} <= target {target:.4g}, "
            f"{early['cancelled']} tasks cancelled in flight)"
        )
    else:
        print(f"WARN: early stopping did not trigger as configured — see {json_path}")
    if not ok_replay:
        raise AssertionError("runtime event logs diverged across replays")
    return rows
