"""Serve path: job admission through executor backends + adaptive-vs-static deadlines.

Writes ``results/bench/BENCH_serve.json`` (plus a CSV row per backend) recording,
for one synthetic regression job admitted through :class:`repro.serve.SolveServer`:

  1. **error-vs-wallclock per backend** — the same seeded job on ``inline`` /
     ``thread`` / ``process`` executors: the simulated error trace (identical by
     the determinism contract — hashes recorded and asserted) plus the *measured*
     wall seconds each backend needs to realize it (the real cost of process
     isolation vs thread concurrency vs no concurrency);
  2. **adaptive vs static deadlines** — the same straggler-heavy job under a
     mis-set static deadline vs an :class:`repro.runtime.AdaptiveDeadline`
     (rolling p95 from the telemetry stream): retry/timeout counts, effective q′,
     and final error for both, the claim being that adaptation recovers the
     retry budget a bad static deadline burns.

Smoke mode (``benchmarks.run --smoke`` / ``test.sh --bench-smoke``) shrinks the
problem and drops the ``process`` backend (spawn + per-child jit dominate).
"""
from __future__ import annotations

import hashlib
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import RESULTS_DIR, print_table, smoke, write_csv
from repro.analysis.annotations import sanctioned_wall_timer
from repro import runtime as rt
from repro.core import sketches as sk, solve
from repro.serve import SolveServer


def _problem(n, d):
    key = jax.random.PRNGKey(0)
    A = jax.random.normal(key, (n, d))
    x_true = jax.random.normal(jax.random.PRNGKey(1), (d,))
    b = A @ x_true + 0.5 * jax.random.normal(jax.random.PRNGKey(2), (n,))
    x_star = solve.lstsq(A, b)
    f_star = float(solve.residual_cost(A, b, x_star))
    return key, A, b, f_star


def _rel_err(A, b, f_star, x) -> float:
    f = float(solve.residual_cost(A, b, jnp.asarray(x, A.dtype)))
    return (f - f_star) / max(f_star, 1e-30)


@sanctioned_wall_timer  # measures real wall cost per backend for the identical simulated job
def run(quick: bool = True):
    if smoke():
        n, d, m, q = 1024, 16, 128, 8
        backends = ["inline", "thread"]
    else:
        n, d, m, q = (8192, 32, 256, 16) if quick else (65536, 128, 1024, 32)
        backends = ["inline", "thread", "process"]
    key, A, b, f_star = _problem(n, d)
    spec = sk.SketchSpec("gaussian", m)
    latency = rt.DropLatency(
        seed=7, inner=rt.LognormalLatency(seed=7, mean_s=1.0, sigma=0.6), drop_prob=0.15
    )
    cfg = rt.RuntimeConfig(deadline_s=2.0, max_retries=2, backoff_base_s=0.1, max_threads=4)

    # ---- 1. the same job on every backend: identical telemetry, measured wall cost
    rows, hashes, xhashes = [], {}, {}
    for backend in backends:
        server = SolveServer(latency=latency, config=cfg, backend=backend)
        t0 = time.perf_counter()
        job = server.submit_solve(A, b, spec, q=q, seed=3)
        wall = time.perf_counter() - t0
        s = job.summary
        log = "\n".join(job.result.events.lines())
        hashes[backend] = hashlib.sha256(log.encode()).hexdigest()
        xhashes[backend] = hashlib.sha256(np.ascontiguousarray(job.xbar).tobytes()).hexdigest()
        rows.append(
            {
                "backend": backend,
                "q": q,
                "effective_q": s["effective_q"],
                "retries": s["retries"],
                "timeouts": s["timeouts"],
                "drops": s["drops"],
                "sim_makespan_s": s["sim_makespan_s"],
                "wall_s": wall,
                "rel_err": _rel_err(A, b, f_star, job.xbar),
            }
        )
    cross_identical = len(set(hashes.values())) == 1 and len(set(xhashes.values())) == 1

    # ---- 2. adaptive vs static deadlines under a mis-set cutoff: the static
    # deadline sits below the latency median, so attempt after attempt times out;
    # the adaptive policy reads the timeout stream, escalates past the median,
    # and spends the same retry budget landing results instead of burning it.
    strag = rt.LognormalLatency(seed=11, mean_s=1.0, sigma=0.4)
    tight = 0.6  # ~p10 of the lognormal: a confidently wrong warm-up guess
    dl_cfg = rt.RuntimeConfig(deadline_s=tight, max_retries=3, backoff_base_s=0.05, max_threads=4)
    deadline_rows = []
    for policy_name, deadline in (
        ("static", None),
        ("adaptive", rt.AdaptiveDeadline(warmup_s=tight, min_samples=3, quantile=0.95)),
    ):
        server = SolveServer(latency=strag, config=dl_cfg, backend="thread", deadline=deadline)
        job = server.submit_solve(A, b, spec, q=q, seed=5)
        s = job.summary
        deadline_rows.append(
            {
                "deadline_policy": policy_name,
                "q": q,
                "effective_q": s["effective_q"],
                "retries": s["retries"],
                "timeouts": s["timeouts"],
                "sim_makespan_s": s["sim_makespan_s"],
                "rel_err": _rel_err(A, b, f_star, job.xbar),
            }
        )
    static_row = deadline_rows[0]
    adaptive_row = deadline_rows[1]
    adaptive_wins = (
        adaptive_row["effective_q"] > static_row["effective_q"]
        and adaptive_row["timeouts"] < static_row["timeouts"]
    )

    summary = {
        "backend": jax.default_backend(),
        "problem": {"n": n, "d": d, "m": m, "q": q, "kind": spec.kind},
        "rows": rows,
        "event_log_sha256": hashes,
        "xbar_sha256": xhashes,
        "cross_backend_identical": cross_identical,
        "deadline_rows": deadline_rows,
        "adaptive_beats_static": adaptive_wins,
    }
    write_csv("serve_bench", rows)
    write_csv("serve_bench_deadlines", deadline_rows)
    os.makedirs(RESULTS_DIR, exist_ok=True)
    json_path = os.path.join(RESULTS_DIR, "BENCH_serve.json")
    with open(json_path, "w") as f:
        json.dump(summary, f, indent=2)
    print_table("serve path: one job per executor backend", rows)
    print_table("serve path: adaptive vs static deadlines (mis-set cutoff)", deadline_rows)
    print(f"JSON summary: {json_path}")

    print(
        ("PASS" if cross_identical else "FAIL")
        + ": byte-identical event log + bitwise x̄ across backends"
    )
    if adaptive_wins:
        print(
            f"PASS: adaptive deadlines recover the budget — q' "
            f"{static_row['effective_q']}→{adaptive_row['effective_q']}, timeouts "
            f"{static_row['timeouts']}→{adaptive_row['timeouts']}"
        )
    else:
        print(f"WARN: adaptive deadlines did not beat static as configured — see {json_path}")
    if not cross_identical:
        raise AssertionError("serve jobs diverged across executor backends")
    return rows
