"""Benchmark orchestrator: one module per paper table/figure + framework extras.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only fig1,thm1,...]

Each module writes results/bench/<name>.csv and prints a table; this runner
aggregates pass/fail-style summaries where a benchmark encodes a checkable claim.
"""
from __future__ import annotations

import argparse
import os
import sys
import time
import traceback

from repro.analysis.annotations import sanctioned_wall_timer

from benchmarks import (
    bias_bounds,
    fig1_airline,
    fig2_emnist,
    fig3_synthetic,
    fig4_leastnorm,
    fused_solve_bench,
    gradcomp_bench,
    ihs_baseline,
    kernel_bench,
    multiworker_gram_bench,
    privacy_bound,
    runtime_bench,
    serve_bench,
    sketch_dp_ablation,
    sketch_ops_bench,
    thm1_validation,
)

MODULES = {
    "thm1": thm1_validation,
    "bias": bias_bounds,
    "privacy": privacy_bound,
    "fig1": fig1_airline,
    "fig2": fig2_emnist,
    "fig3": fig3_synthetic,
    "fig4": fig4_leastnorm,
    "ihs": ihs_baseline,
    "gradcomp": gradcomp_bench,
    "sketch_dp": sketch_dp_ablation,
    "kernels": kernel_bench,
    "sketch_ops": sketch_ops_bench,
    "fused": fused_solve_bench,
    "multiworker": multiworker_gram_bench,
    "runtime": runtime_bench,
    "serve": serve_bench,
}


@sanctioned_wall_timer  # per-benchmark wall cost in the progress lines
def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale sizes (slow)")
    ap.add_argument("--only", default="", help="comma-separated module keys")
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="one tiny shape per benchmark (sets REPRO_BENCH_SMOKE=1) — the "
        "./test.sh --bench-smoke CI mode; numbers are not meaningful",
    )
    args = ap.parse_args(argv)
    if args.smoke:
        os.environ["REPRO_BENCH_SMOKE"] = "1"

    keys = [k.strip() for k in args.only.split(",") if k.strip()] or list(MODULES)
    unknown = sorted(k for k in keys if k not in MODULES)
    if unknown:
        print(
            f"benchmarks.run: unknown benchmark key(s) {', '.join(unknown)}; "
            f"registered keys: {', '.join(sorted(MODULES))}",
            file=sys.stderr,
        )
        return 2
    failures = []
    for k in keys:
        mod = MODULES[k]
        t0 = time.time()
        print(f"\n########## {k} ({mod.__name__}) ##########", flush=True)
        try:
            mod.run(quick=not args.full)
            print(f"[{k}] done in {time.time()-t0:.1f}s", flush=True)
        except Exception:
            traceback.print_exc()
            failures.append(k)
    if failures:
        print(f"\nFAILED benchmarks: {failures}")
        return 1
    print(f"\nAll {len(keys)} benchmarks completed; CSVs in results/bench/")
    return 0


if __name__ == "__main__":
    sys.exit(main())
