"""Operator-layer benchmark: batched multi-worker application vs a per-worker loop,
and blocked streaming vs one-shot application.

Two claims are measured and recorded in ``results/bench/BENCH_sketch_ops.json``:

  1. ``apply_batched`` (q workers vmapped over one read of A) beats a Python loop of
     q jit'd per-worker applies — the pattern Algorithm 1's master-sketch mode, IHS,
     and head fitting now use. On CPU the win comes from amortizing q dispatches;
     on TPU it additionally amortizes HBM reads of A and fills the MXU, so the quick
     sizes sit in the dispatch-bound regime that is measurable on this container.
  2. ``apply_blocked`` reproduces ``apply`` to ~1e-5 on n not divisible by the block
     size (the counter-RNG tiles are pure functions of (key, i, j)), while holding
     only O(block_rows · d) of A live — the out-of-core path.

Loop-vs-batched pairs are timed interleaved with min-of-N (the least-contended
sample), the standard way to de-noise microbenchmarks on shared hosts.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import operators as ops, sketches as sk
from benchmarks.common import RESULTS_DIR, block, print_table, timeit, write_csv
from repro.analysis.annotations import sanctioned_wall_timer

Q = 8


@sanctioned_wall_timer
def _time_pair(fn_a, fn_b, repeat: int = 15):
    """Interleaved min-of-``repeat`` wall seconds for two thunks (after warmup)."""
    block(fn_a())
    block(fn_b())
    ta, tb = [], []
    for _ in range(repeat):
        t0 = time.perf_counter()
        block(fn_a())
        ta.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        block(fn_b())
        tb.append(time.perf_counter() - t0)
    return min(ta), min(tb)


def _specs(quick: bool):
    m = 128 if quick else 1024
    return [
        ("gaussian", sk.SketchSpec("gaussian", m)),
        ("sjlt_s4", sk.SketchSpec("sjlt", m, s=4)),
        ("srht", sk.SketchSpec("srht", m)),
    ]


def run(quick: bool = True):
    smoke = os.environ.get("REPRO_BENCH_SMOKE", "") == "1"
    n, d = (2048, 32) if quick else (65536, 128)
    repeat = 3 if smoke else 15
    backend = jax.default_backend()
    key = jax.random.PRNGKey(0)
    A = jax.random.normal(key, (n, d), jnp.float32)
    keys = jax.random.split(jax.random.PRNGKey(1), Q)

    rows = []
    summary = {"backend": backend, "n": n, "d": d, "q": Q}
    for name, spec in _specs(quick):
        batched = jax.jit(lambda ks, A, spec=spec: ops.apply_batched(spec, ks, A))
        single = jax.jit(lambda k, A, spec=spec: ops.apply(spec, k, A))

        def loop():
            return jnp.stack([single(keys[i], A) for i in range(Q)])

        t_loop, t_batched = _time_pair(loop, lambda: batched(keys, A), repeat=repeat)

        # correctness of the batched path against the loop it replaces
        err_batched = float(jnp.max(jnp.abs(batched(keys, A) - loop())))

        # blocked streaming: block size chosen to NOT divide n
        block_rows = 96
        op = ops.make_operator(spec, keys[0], n)
        blocked = jax.jit(lambda A, op=op: op.apply_blocked(A, block_rows=block_rows))
        one_shot = jax.jit(lambda A, op=op: op.apply(A))
        t_oneshot, t_blocked = _time_pair(lambda: one_shot(A), lambda: blocked(A))
        err_blocked = float(jnp.max(jnp.abs(blocked(A) - one_shot(A))))
        ref_scale = max(1.0, float(jnp.max(jnp.abs(one_shot(A)))))

        gbps = Q * 4 * n * d / t_batched / 1e9  # q reads of A per batched call
        rows.append(
            {
                "sketch": name,
                "backend": backend,
                "n": n,
                "d": d,
                "loop_ms": t_loop * 1e3,
                "batched_ms": t_batched * 1e3,
                "batched_speedup": t_loop / t_batched,
                "batched_gbps": gbps,
                "batched_maxerr": err_batched,
                "oneshot_ms": t_oneshot * 1e3,
                "blocked_ms": t_blocked * 1e3,
                "blocked_maxerr": err_blocked,
            }
        )
        summary[name] = {
            "loop_s": t_loop,
            "batched_s": t_batched,
            "batched_speedup": t_loop / t_batched,
            "batched_gbps": gbps,
            "batched_maxerr": err_batched,
            "blocked_maxerr_at_block96": err_blocked,
            "blocked_matches_1e-5": bool(err_blocked < 1e-5 * ref_scale),
        }

    write_csv("sketch_ops_bench", rows)
    os.makedirs(RESULTS_DIR, exist_ok=True)
    json_path = os.path.join(RESULTS_DIR, "BENCH_sketch_ops.json")
    with open(json_path, "w") as f:
        json.dump(summary, f, indent=2)
    print_table(f"SketchOp batched (q={Q}) vs loop + blocked streaming", rows)
    print(f"JSON summary: {json_path}")

    g = summary["gaussian"]
    if g["batched_speedup"] > 1.0:
        print(f"PASS: apply_batched(q={Q}, gaussian) beats the loop: {g['batched_speedup']:.2f}x")
    else:
        # Speedup is hardware/load-dependent; on a heavily contended host it can
        # dip below 1x. Record, warn, don't fail the whole sweep.
        print(
            f"WARN: apply_batched(q={Q}, gaussian) did not beat the loop on this host "
            f"({g['batched_speedup']:.2f}x) — see {json_path}"
        )
    return rows
