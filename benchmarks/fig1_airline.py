"""Paper Fig. 1: airline-scale regression — sampling vs hybrid (sampling→SJLT).

Offline container: the 1.21e8×774 airline matrix is regenerated as dummy-coded
categorical data with the same structure (see data/regression.airline_like), scaled
down, preserving the regime n ≫ m ≫ d. Both the real 0/1 target (plots a/b) and the
planted target (plots c/d) are run. Error-vs-time curves come from the lognormal
worker-runtime model with the paper's measured per-sketch run times as means
(sampling 37.5 s, +SJLT 43.9 s — Fig. 1 caption) scaled to our problem size.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import averaging, sketches as sk, solve
from repro.data import airline_like
from repro.utils import prng
from benchmarks.common import print_table, simulate_worker_times, smoke, write_csv


def _curve(A, b, f_star, spec, key, q, runtimes):
    """Approximation error after averaging the workers that finished by time t."""
    def worker(w):
        return solve.sketch_and_solve(spec, prng.worker_key(key, w), A, b, method="chol")

    xs = jax.lax.map(worker, jnp.arange(q), batch_size=8)  # (q, d)
    order = np.argsort(runtimes)
    rows = []
    for k in (1, 2, 5, 10, 20, q):
        if k > q:
            break
        mask = np.zeros(q, np.float32)
        mask[order[:k]] = 1.0
        xbar = averaging.masked_average(xs, jnp.asarray(mask))
        err = float(solve.relative_error(A, b, xbar, f_star))
        rows.append({"avg_outputs": k, "time_s": float(runtimes[order[k - 1]]), "rel_err": err})
    return rows


def run(quick: bool = True):
    n = 100_000 if quick else 1_000_000
    q = 25 if quick else 100
    if smoke():
        n, q = 4096, 4
    key = jax.random.PRNGKey(0)
    A, b_real, meta = airline_like(key, n)
    d = meta["d"]
    m, m_prime = (16 * d, 64 * d) if quick else (32 * d, 128 * d)
    if smoke():
        m, m_prime = 4 * d, 16 * d  # keep m' <= n at the tiny shape

    x_star = solve.lstsq(A, b_real)
    f_star_real = float(solve.residual_cost(A, b_real, x_star))
    b_plant = A @ meta["x_truth"] + 0.1 * jax.random.normal(jax.random.PRNGKey(1), (n,))
    f_star_plant = float(solve.residual_cost(A, b_plant, solve.lstsq(A, b_plant)))

    specs = {
        "sampling": sk.SketchSpec("uniform", m, replacement=False),
        "hybrid_sjlt": sk.SketchSpec("hybrid", m, m_prime=m_prime, inner="sjlt", s=4),
    }
    # paper-measured lambda runtimes (s) per sketch, scaled to our n
    mean_times = {"sampling": 37.5, "hybrid_sjlt": 43.9}

    rows = []
    for target, b, fs in (("real", b_real, f_star_real), ("planted", b_plant, f_star_plant)):
        for name, spec in specs.items():
            runtimes = simulate_worker_times(
                jax.random.PRNGKey(hash(name) % 2**31), q, mean_s=mean_times[name] * n / 1.21e8
            )
            for r in _curve(A, b, fs, spec, key, q, runtimes):
                rows.append({"target": target, "sketch": name, **r})

    write_csv("fig1_airline", rows)
    print_table("Fig.1 airline-like: sampling vs hybrid(SJLT)", rows)
    return rows


if __name__ == "__main__":
    run(quick=True)
