"""Paper Fig. 3: large-scale student-t synthetic data — hybrid vs plain sampling.

Heavy-tailed rows (student-t, df = 1.5 / 1.7) are the regime where uniform sampling
is badly biased (rows have wildly uneven leverage) and the hybrid sketch's second
stage (SJLT over the sampled block) recovers most of the gap — the paper's Fig. 3
trend: 'hybrid reaches a lower error floor but takes longer per worker'.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import averaging, sketches as sk, solve
from repro.data import student_t_regression
from repro.utils import prng
from benchmarks.common import print_table, simulate_worker_times, smoke, write_csv
import numpy as np


def run(quick: bool = True):
    n, d = (200_000, 128) if quick else (2_000_000, 512)
    q = 32 if quick else 200
    if smoke():
        n, d, q = 8192, 32, 4
    m, m_prime = (10 * d, 50 * d)
    rows = []
    for df in (1.5, 1.7):
        key = jax.random.PRNGKey(int(df * 10))
        A, b, _ = student_t_regression(key, n, d, df=df)
        x_star = solve.lstsq(A, b)
        f_star = float(solve.residual_cost(A, b, x_star))
        specs = {
            "sampling": sk.SketchSpec("uniform", m, replacement=False),
            "hybrid_sjlt": sk.SketchSpec("hybrid", m, m_prime=m_prime, inner="sjlt", s=4),
        }
        mean_times = {"sampling": 1.0, "hybrid_sjlt": 1.35}  # paper: hybrid ~35% slower
        for name, spec in specs.items():
            def worker(w):
                return solve.sketch_and_solve(spec, prng.worker_key(key, w), A, b, method="chol")

            xs = jax.lax.map(worker, jnp.arange(q), batch_size=8)
            runtimes = simulate_worker_times(jax.random.PRNGKey(hash(name) % 2**31), q, mean_s=mean_times[name])
            order = np.argsort(runtimes)
            for kk in sorted({k for k in (1, 4, 16, q) if k <= q}):
                mask = np.zeros(q, np.float32)
                mask[order[:kk]] = 1.0
                xbar = averaging.masked_average(xs, jnp.asarray(mask))
                rows.append(
                    {
                        "df": df, "sketch": name, "avg_outputs": kk,
                        "time_s": float(runtimes[order[kk - 1]]),
                        "rel_err": float(solve.relative_error(A, b, xbar, f_star)),
                    }
                )
    write_csv("fig3_synthetic", rows)
    print_table("Fig.3 student-t: sampling vs hybrid", rows)
    return rows


if __name__ == "__main__":
    run(quick=True)
