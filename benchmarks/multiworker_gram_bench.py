"""Multi-worker fused Gram: one launch for all q sketches vs the per-worker loop,
and the cheap counter-RNG Rademacher family vs the Gaussian draw.

Writes ``results/bench/BENCH_multiworker_gram.json``. Three claims:

  1. Fused q-worker launch (``*_gram_multi``, what ``operators.gram_batched``
     dispatches to for kernel-routed specs) reads A once for all q workers
     instead of q times — ``fused_vs_loop`` per family.
  2. The Rademacher family replaces the per-entry threefry + Box-Muller Gaussian
     draw with one threefry word per 32 entries (``rng_share`` =
     t(gaussian)/t(rademacher) at equal shapes, fused mode).
  3. The headline: the status-quo path before this PR was a per-worker loop of
     Gaussian gram launches; the new path is the fused multi-worker Rademacher
     launch. ``headline_speedup`` = t(gaussian loop)/t(rademacher fused) must be
     ≥ 1.5x at q=8, n=131072, d=256, m=1024.

An extra subprocess row times the gaussian fused gram under REPRO_RNG_ROUNDS=8
(the reduced-round threefry variant; trace-time knob, hence the subprocess)
against the 20-round default in identical conditions.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
import time

import jax
import jax.numpy as jnp

from benchmarks.common import RESULTS_DIR, block, print_table, smoke, write_csv
from repro.analysis.annotations import sanctioned_wall_timer
from repro.utils import prng

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# Acceptance shape: q workers each sketching the same (n, d) A down to m rows.
FULL_SHAPE = dict(q=8, n=131072, d=256, m=1024)
SMOKE_SHAPE = dict(q=4, n=4096, d=64, m=128)


@sanctioned_wall_timer
def _time(fn, repeat: int) -> float:
    block(fn())
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        block(fn())
        ts.append(time.perf_counter() - t0)
    return min(ts)


def _family_fns(family: str, m: int):
    if family == "gaussian":
        from repro.kernels.gaussian import ops as fam_ops

        return (
            lambda keys, A: fam_ops.gaussian_gram_multi(keys, A, m),
            lambda key, A: fam_ops.gaussian_gram(key, A, m),
        )
    from repro.kernels.rademacher import ops as fam_ops

    return (
        lambda keys, A: fam_ops.rademacher_gram_multi(keys, A, m),
        lambda key, A: fam_ops.rademacher_gram(key, A, m),
    )


def _bench_reduced_rounds(shape: dict, repeat: int) -> dict:
    """REPRO_RNG_ROUNDS is resolved at trace time, so both variants are traced and
    timed inside one subprocess with the env flipped between traces."""
    script = textwrap.dedent(
        f"""
        import os, json, time
        import jax, jax.numpy as jnp
        from repro.kernels.gaussian import ops as gops
        from repro.utils import prng

        q, n, d, m = {shape["q"]}, {shape["n"]}, {shape["d"]}, {shape["m"]}
        A = jax.random.normal(jax.random.PRNGKey(0), (n, d), jnp.float32)
        keys = prng.worker_keys(jax.random.PRNGKey(1), q)

        def timeit(fn, repeat={repeat}):
            jax.block_until_ready(fn())
            ts = []
            for _ in range(repeat):
                t0 = time.perf_counter()
                jax.block_until_ready(fn())
                ts.append(time.perf_counter() - t0)
            return min(ts)

        os.environ["REPRO_RNG_ROUNDS"] = "20"
        t20 = timeit(jax.jit(lambda: gops.gaussian_gram_multi(keys, A, m)))
        os.environ["REPRO_RNG_ROUNDS"] = "8"
        t8 = timeit(jax.jit(lambda: gops.gaussian_gram_multi(keys, A, m)))
        print(json.dumps({{"rounds20_s": t20, "rounds8_s": t8, "speedup": t20 / t8}}))
        """
    )
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True, timeout=1800, env=env
    )
    if out.returncode != 0:
        print(f"WARN: reduced-rounds subprocess failed:\n{out.stderr[-2000:]}")
        return {"error": "subprocess failed"}
    return json.loads(out.stdout.strip().splitlines()[-1])


def run(quick: bool = True):
    shape = SMOKE_SHAPE if smoke() else FULL_SHAPE
    q, n, d, m = shape["q"], shape["n"], shape["d"], shape["m"]
    repeat = 2 if smoke() else 3

    A = jax.random.normal(jax.random.PRNGKey(0), (n, d), jnp.float32)
    keys = prng.worker_keys(jax.random.PRNGKey(1), q)

    rows = []
    times = {}
    for family in ("gaussian", "rademacher"):
        multi, single = _family_fns(family, m)
        fused = jax.jit(lambda keys=keys, A=A, multi=multi: multi(keys, A))
        loop = jax.jit(
            lambda keys=keys, A=A, single=single: jax.lax.map(lambda k: single(k, A), keys)
        )
        t_fused = _time(fused, repeat)
        t_loop = _time(loop, repeat)
        # parity sanity: fused worker slices == loop worker slices, bitwise
        same = bool(jnp.all(fused() == loop()))
        times[family] = {"fused": t_fused, "loop": t_loop}
        for mode, t in (("loop", t_loop), ("fused", t_fused)):
            rows.append(
                {
                    "family": family,
                    "mode": mode,
                    "q": q,
                    "n": n,
                    "d": d,
                    "m": m,
                    "ms": t * 1e3,
                    "fused_vs_loop": t_loop / t_fused if mode == "fused" else 1.0,
                    "bitwise_match": same,
                }
            )

    summary = {
        "backend": jax.default_backend(),
        "shape": shape,
        "rows": rows,
        "fused_vs_loop": {
            fam: times[fam]["loop"] / times[fam]["fused"] for fam in times
        },
        # RNG share at equal shape/mode: the matmul work is identical, so the gap
        # is the Gaussian draw (threefry + Box-Muller per entry vs 1 word / 32).
        "rng_share_fused": times["gaussian"]["fused"] / times["rademacher"]["fused"],
        # Status quo before this PR (per-worker Gaussian gram launches) vs the
        # new path (one Rademacher launch for all q workers).
        "headline_speedup": times["gaussian"]["loop"] / times["rademacher"]["fused"],
        "reduced_rounds_gaussian": _bench_reduced_rounds(shape, repeat),
    }

    write_csv("multiworker_gram_bench", rows)
    os.makedirs(RESULTS_DIR, exist_ok=True)
    json_path = os.path.join(RESULTS_DIR, "BENCH_multiworker_gram.json")
    with open(json_path, "w") as f:
        json.dump(summary, f, indent=2)
    print_table("multi-worker gram: fused single launch vs per-worker loop", rows)
    print(f"JSON summary: {json_path}")

    h = summary["headline_speedup"]
    if smoke():
        print("SMOKE: shapes are tiny; speedup numbers not meaningful")
    elif h >= 1.5:
        print(
            f"PASS: fused multi-worker rademacher gram {h:.2f}x over the per-worker "
            f"gaussian loop at q={q} n={n} d={d} m={m}"
        )
    else:
        print(f"WARN: headline speedup {h:.2f}x < 1.5x on this host — see {json_path}")
    return rows
